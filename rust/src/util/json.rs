//! Minimal JSON: a recursive-descent parser and a writer.
//!
//! Used for experiment configs, artifact metadata (`artifacts/<preset>/
//! meta.json`, written by `python/compile/aot.py`) and metric dumps under
//! `results/`. `serde` is not in the offline crate set (DESIGN.md §6); this
//! covers the full JSON grammar we produce and consume.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are sorted (BTreeMap) so output is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    // ---- constructors / accessors -------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics if not an object — construction bug).
    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// `get` + `as_f64` with a default.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }

    /// `get` + `as_usize`, error if missing (for required config fields).
    pub fn require_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid field `{key}`"))
    }

    pub fn require_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid field `{key}`"))
    }

    // ---- helpers for building -----------------------------------------

    pub fn from_f64s(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn from_f32s(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn from_usizes(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---- parsing -------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    /// Parse a JSON file.
    pub fn from_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?)
    }

    /// Write (pretty) to a file, creating parent dirs.
    pub fn to_file(&self, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, format!("{self:#}"))?;
        Ok(())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // Surrogates are not produced by our writers;
                            // map unpaired ones to the replacement char.
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\t' => f.write_str("\\t")?,
            '\r' => f.write_str("\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

fn fmt_num(f: &mut fmt::Formatter<'_>, n: f64) -> fmt::Result {
    if !n.is_finite() {
        // JSON has no NaN/Inf; encode as null like most writers in practice.
        return f.write_str("null");
    }
    if n == n.trunc() && n.abs() < 1e15 {
        write!(f, "{}", n as i64)
    } else {
        write!(f, "{n}")
    }
}

impl fmt::Display for Json {
    /// Compact by default; `{:#}` pretty-prints with 2-space indent.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fn go(v: &Json, f: &mut fmt::Formatter<'_>, pretty: bool, depth: usize) -> fmt::Result {
            let pad = |f: &mut fmt::Formatter<'_>, d: usize| -> fmt::Result {
                if pretty {
                    f.write_str("\n")?;
                    for _ in 0..d {
                        f.write_str("  ")?;
                    }
                }
                Ok(())
            };
            match v {
                Json::Null => f.write_str("null"),
                Json::Bool(b) => write!(f, "{b}"),
                Json::Num(n) => fmt_num(f, *n),
                Json::Str(s) => write_escaped(f, s),
                Json::Arr(a) => {
                    if a.is_empty() {
                        return f.write_str("[]");
                    }
                    f.write_str("[")?;
                    for (i, x) in a.iter().enumerate() {
                        if i > 0 {
                            f.write_str(",")?;
                        }
                        pad(f, depth + 1)?;
                        go(x, f, pretty, depth + 1)?;
                    }
                    pad(f, depth)?;
                    f.write_str("]")
                }
                Json::Obj(m) => {
                    if m.is_empty() {
                        return f.write_str("{}");
                    }
                    f.write_str("{")?;
                    for (i, (k, x)) in m.iter().enumerate() {
                        if i > 0 {
                            f.write_str(",")?;
                        }
                        pad(f, depth + 1)?;
                        write_escaped(f, k)?;
                        f.write_str(if pretty { ": " } else { ":" })?;
                        go(x, f, pretty, depth + 1)?;
                    }
                    pad(f, depth)?;
                    f.write_str("}")
                }
            }
        }
        go(self, f, f.alternate(), 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\n"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        let re2 = Json::parse(&format!("{v:#}")).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }

    #[test]
    fn builder_helpers() {
        let mut o = Json::obj();
        o.set("xs", Json::from_f64s(&[1.0, 2.0]))
            .set("name", Json::Str("t".into()));
        assert_eq!(o.require_str("name").unwrap(), "t");
        assert_eq!(o.get("xs").unwrap().as_arr().unwrap().len(), 2);
        assert!(o.require_usize("missing").is_err());
    }

    #[test]
    fn nonfinite_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }
}
