//! Leveled stderr logging with an env-style filter (`MKOR_LOG=debug`).
//!
//! Deliberately tiny: the offline crate set has `log` but no emitter, and
//! the coordinator only needs timestamped leveled lines.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

/// Log verbosity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // Info

/// Initialize from `MKOR_LOG` (quiet|error|warn|info|debug). Safe to call
/// twice. `quiet` keeps warnings/errors but silences Info-level progress
/// output (the CLI's `--quiet`-equivalent, as an env knob).
pub fn init_from_env() {
    if let Ok(v) = std::env::var("MKOR_LOG") {
        let lvl = match v.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" | "quiet" => Level::Warn,
            "debug" => Level::Debug,
            _ => Level::Info,
        };
        set_level(lvl);
    }
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Emit a line (used by the macros below).
pub fn emit(l: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default()
        .as_secs_f64();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{t:.3} {tag}] {args}");
}

/// `info!`-style macros scoped to this crate.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Info, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Warn, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Error, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Debug, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }
}
