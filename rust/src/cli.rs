//! Minimal CLI argument parsing (clap is not in the offline crate set).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args —
//! enough for the `mkor` binary's subcommands and all examples.

use std::collections::BTreeMap;

/// Parsed arguments: positionals in order plus `--key [value]` options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` unless the next token is another flag.
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.options.insert(stripped.to_string(), v);
                        }
                        _ => {
                            out.options.insert(stripped.to_string(), String::from("true"));
                        }
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process args.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Is `--key` set to a truthy value? Bare `--flag` (stored as
    /// `"true"`), `--flag=1`, `--flag=yes`, `--flag=on` and their
    /// case-insensitive variants all count; `--flag=false`/`0`/`no`/`off`
    /// (and any other value) do not.
    pub fn flag(&self, key: &str) -> bool {
        match self.options.get(key) {
            Some(v) => matches!(
                v.to_ascii_lowercase().as_str(),
                "true" | "1" | "yes" | "on"
            ),
            None => false,
        }
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// First positional (subcommand), if any.
    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(String::as_str)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positional_and_options() {
        let a = parse("train --preset small --steps 100 --verbose --lr=0.05 extra");
        assert_eq!(a.command(), Some("train"));
        assert_eq!(a.get("preset"), Some("small"));
        assert_eq!(a.usize_or("steps", 0), 100);
        assert!(a.flag("verbose"));
        assert!((a.f32_or("lr", 0.0) - 0.05).abs() < 1e-9);
        assert_eq!(a.positional, vec!["train", "extra"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("bench");
        assert_eq!(a.usize_or("workers", 4), 4);
        assert_eq!(a.get_or("preset", "tiny"), "tiny");
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn negative_numbers_are_values_not_flags() {
        let a = parse("--offset -3");
        // "-3" doesn't start with --, so it's consumed as the value.
        assert_eq!(a.get("offset"), Some("-3"));
    }

    #[test]
    fn truthy_flag_forms_all_read_as_set() {
        for form in [
            "--verbose",
            "--verbose=true",
            "--verbose=TRUE",
            "--verbose=1",
            "--verbose=yes",
            "--verbose=on",
            "--verbose true",
            "--verbose 1",
            "--verbose yes",
        ] {
            let a = parse(form);
            assert!(a.flag("verbose"), "`{form}` should read as set");
        }
    }

    #[test]
    fn falsy_and_unrelated_values_read_as_unset() {
        for form in ["--verbose=false", "--verbose=0", "--verbose=no", "--verbose=off"] {
            let a = parse(form);
            assert!(!a.flag("verbose"), "`{form}` should read as unset");
        }
        // An option carrying an ordinary value is not a set flag...
        let a = parse("--preset small");
        assert!(!a.flag("preset"));
        // ...and an absent key never is.
        assert!(!a.flag("missing"));
    }
}
