//! # MKOR — Momentum-Enabled Kronecker-Factor-Based Optimizer Using Rank-1 Updates
//!
//! Full-system reproduction of the NeurIPS 2023 paper as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the distributed-training coordinator: data-parallel
//!   workers, ring all-reduce (fp32 + bf16-quantized rank-1 sync), the
//!   inversion-frequency scheduler, the MKOR-H loss-rate switcher, the
//!   norm-based stabilizer, metrics, the spec-driven sweep engine
//!   ([`sweep`]: thread-pool and multi-process fan-out with byte-identical
//!   deterministic artifacts), the checkpoint subsystem ([`checkpoint`]:
//!   durable optimizer/model state, resumable runs and sweeps) and the CLI.
//!   `docs/ARCHITECTURE.md` maps every module to the paper.
//! * **L2 (JAX, build time)** — transformer fwd/bwd and the fused `mkor_step`
//!   optimizer graph, AOT-lowered to HLO text under `artifacts/`.
//! * **L1 (Pallas, build time)** — the Sherman–Morrison rank-1 inverse-update
//!   and preconditioning kernels, lowered into the same HLO.
//!
//! Python never runs on the training path: [`runtime`] loads the artifacts via
//! the PJRT C API and executes them from Rust.
//!
//! The crate also contains pure-Rust implementations of MKOR
//! ([`optim::mkor`]) and of every baseline the paper compares against (KFAC/
//! KAISA, SNGD/HyLo, Eva, SGD-momentum, Adam, LAMB) plus the substrates they
//! need (dense linear algebra, synthetic workloads, a Rust-native NN with
//! per-layer activation/gradient capture, collectives, a cluster cost model).
//! See `DESIGN.md` for the system inventory and the experiment index.

pub mod bench_utils;
pub mod checkpoint;
pub mod cli;
pub mod collective;
pub mod coordinator;
pub mod costmodel;
pub mod data;
pub mod experiments;
pub mod linalg;
pub mod model;
pub mod obs;
pub mod optim;
pub mod perf;
pub mod runtime;
pub mod serve;
pub mod sweep;
pub mod util;

/// Crate version string reported by `mkor --version`.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
