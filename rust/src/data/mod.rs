//! Synthetic workload generators.
//!
//! The paper evaluates on Wikipedia+BookCorpus (BERT pre-training), SQuAD and
//! GLUE (fine-tuning), ImageNet/CIFAR (ResNet/AlexNet). None of those can be
//! shipped here, so each is replaced by a generator that preserves the
//! property the optimizer interacts with (DESIGN.md §3):
//!
//! * [`text`] — a Markov chain with Zipfian emission marginals: token
//!   frequencies follow a power law (like natural language) and there is
//!   learnable sequential structure, so masked-LM loss decreases with
//!   training and differentiates optimizers.
//! * [`classification`] — Gaussian-mixture tasks with controllable class
//!   count/separation/input rank: GLUE proxies of graded difficulty, and
//!   low-rank inputs reproduce the low-rank covariance regime of Figure 5.
//! * [`images`] — template-plus-noise "images" for the autoencoder and
//!   CNN-proxy experiments (CIFAR/ImageNet stand-ins).

pub mod classification;
pub mod images;
pub mod text;

use crate::linalg::Matrix;

/// A supervised batch in column-sample layout (`x`: d×b, one column per
/// sample) with integer labels. This matches the paper's `A ∈ R^{d×b}`.
#[derive(Clone, Debug)]
pub struct Batch {
    pub x: Matrix,
    pub labels: Vec<usize>,
}

impl Batch {
    pub fn batch_size(&self) -> usize {
        self.x.cols()
    }
}

/// A regression/reconstruction batch (targets are dense).
#[derive(Clone, Debug)]
pub struct DenseBatch {
    pub x: Matrix,
    pub y: Matrix,
}
