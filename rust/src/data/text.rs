//! Markov–Zipf synthetic token corpus and masked-LM batches.
//!
//! The generator draws a hidden first-order Markov chain over `states`
//! latent topics; each topic emits tokens from its own Zipfian distribution
//! over a shared vocabulary. The result has (a) power-law unigram
//! frequencies, (b) genuine sequential structure a model can learn, and
//! (c) a tunable entropy floor — which is what makes steps-to-target-loss a
//! meaningful optimizer metric on it.
//!
//! Two consumers: the Rust-native MLP proxies (dense bag-of-context
//! features via [`MlmBatchGen::next_dense`]) and the XLA transformer
//! (token-id batches via [`MlmBatchGen::next_tokens`], fed to the
//! `train_step` artifact).

use crate::linalg::Matrix;
use crate::util::rng::{Rng, Zipf};

/// Corpus generator configuration.
#[derive(Clone, Debug)]
pub struct TextConfig {
    pub vocab: usize,
    /// Hidden Markov states (topics).
    pub states: usize,
    /// Zipf exponent for per-state emission distributions.
    pub zipf_s: f64,
    /// Probability of staying in the current state.
    pub stickiness: f64,
    pub seed: u64,
}

impl Default for TextConfig {
    fn default() -> Self {
        TextConfig { vocab: 1024, states: 16, zipf_s: 1.1, stickiness: 0.85, seed: 0 }
    }
}

/// The corpus process: hidden Markov chain + per-state Zipfian emissions.
pub struct Corpus {
    cfg: TextConfig,
    /// Per-state permutation of token ranks, so states emit different tokens.
    state_perm: Vec<Vec<usize>>,
    zipf: Zipf,
}

impl Corpus {
    pub fn new(cfg: TextConfig) -> Self {
        let mut rng = Rng::new(cfg.seed ^ 0xC0FFEE);
        let state_perm = (0..cfg.states).map(|_| rng.permutation(cfg.vocab)).collect();
        let zipf = Zipf::new(cfg.vocab, cfg.zipf_s);
        Corpus { cfg, state_perm, zipf }
    }

    pub fn vocab(&self) -> usize {
        self.cfg.vocab
    }

    /// Sample a token sequence of length `len`.
    pub fn sample_sequence(&self, len: usize, rng: &mut Rng) -> Vec<u32> {
        let mut state = rng.next_below(self.cfg.states as u64) as usize;
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            if rng.next_f64() > self.cfg.stickiness {
                state = rng.next_below(self.cfg.states as u64) as usize;
            }
            let rank = self.zipf.sample(rng);
            out.push(self.state_perm[state][rank] as u32);
        }
        out
    }
}

/// Masked-LM batch generator over a [`Corpus`].
pub struct MlmBatchGen {
    corpus: Corpus,
    pub seq_len: usize,
    pub mask_prob: f64,
    /// Token id reserved for [MASK] (vocab-1 by convention here).
    pub mask_id: u32,
    rng: Rng,
}

/// A token-level MLM batch: `tokens[b][t]` already has masks applied;
/// `targets[b][t]` is the original token where masked, `u32::MAX` elsewhere.
#[derive(Clone, Debug)]
pub struct TokenBatch {
    pub tokens: Vec<Vec<u32>>,
    pub targets: Vec<Vec<u32>>,
}

impl TokenBatch {
    /// Flatten to i32 buffers for the XLA runtime (masked positions in
    /// `target_mask` are 1.0). Targets at unmasked positions are 0.
    pub fn to_flat(&self) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
        let mut toks = Vec::new();
        let mut tgts = Vec::new();
        let mut mask = Vec::new();
        for (ts, gs) in self.tokens.iter().zip(&self.targets) {
            for (&t, &g) in ts.iter().zip(gs) {
                toks.push(t as i32);
                if g == u32::MAX {
                    tgts.push(0);
                    mask.push(0.0);
                } else {
                    tgts.push(g as i32);
                    mask.push(1.0);
                }
            }
        }
        (toks, tgts, mask)
    }
}

impl MlmBatchGen {
    pub fn new(cfg: TextConfig, seq_len: usize, mask_prob: f64, seed: u64) -> Self {
        let mask_id = (cfg.vocab - 1) as u32;
        MlmBatchGen {
            corpus: Corpus::new(cfg),
            seq_len,
            mask_prob,
            mask_id,
            rng: Rng::new(seed ^ 0xBEEF),
        }
    }

    pub fn vocab(&self) -> usize {
        self.corpus.vocab()
    }

    /// Next batch of `b` masked sequences (for the transformer path).
    pub fn next_tokens(&mut self, b: usize) -> TokenBatch {
        let mut tokens = Vec::with_capacity(b);
        let mut targets = Vec::with_capacity(b);
        for _ in 0..b {
            let seq = self.corpus.sample_sequence(self.seq_len, &mut self.rng);
            let mut masked = seq.clone();
            let mut tgt = vec![u32::MAX; self.seq_len];
            let mut any = false;
            for t in 0..self.seq_len {
                if self.rng.next_f64() < self.mask_prob {
                    tgt[t] = seq[t];
                    masked[t] = self.mask_id;
                    any = true;
                }
            }
            if !any {
                // Guarantee at least one prediction target per sequence.
                let t = self.rng.next_below(self.seq_len as u64) as usize;
                tgt[t] = seq[t];
                masked[t] = self.mask_id;
            }
            tokens.push(masked);
            targets.push(tgt);
        }
        TokenBatch { tokens, targets }
    }

    /// Next dense batch for the MLP proxy: predict the token at a masked
    /// position from a bag-of-context feature vector (normalized counts of
    /// the `window` surrounding tokens, hashed into `feat_dim` buckets).
    pub fn next_dense(&mut self, b: usize, feat_dim: usize, window: usize) -> crate::data::Batch {
        let mut x = Matrix::zeros(feat_dim, b);
        let mut labels = Vec::with_capacity(b);
        for col in 0..b {
            let seq = self.corpus.sample_sequence(self.seq_len, &mut self.rng);
            let pos = self.rng.next_below(self.seq_len as u64) as usize;
            labels.push(seq[pos] as usize);
            let lo = pos.saturating_sub(window);
            let hi = (pos + window + 1).min(self.seq_len);
            let mut count = 0.0f32;
            for (t, &tok) in seq.iter().enumerate().take(hi).skip(lo) {
                if t == pos {
                    continue;
                }
                // Direct token-count features (exact when feat_dim ≥ vocab,
                // folded otherwise). Zipfian token frequencies make these
                // features strongly anisotropic — the ill-conditioned
                // activation-covariance regime second-order methods target.
                x[(tok as usize % feat_dim, col)] += 1.0;
                count += 1.0;
            }
            if count > 0.0 {
                for i in 0..feat_dim {
                    x[(i, col)] /= count;
                }
            }
        }
        crate::data::Batch { x, labels }
    }
}

/// A causal-LM batch: `x` is a `seq_len×b` matrix of token ids (f32 — the
/// [`Transformer`](crate::model::Transformer) reads them back as indices);
/// `labels[j·seq_len + t]` is sample `j`'s NEXT token after position `t`,
/// matching the model's unrolled output-column order so the batch plugs
/// straight into `softmax_xent`.
#[derive(Clone, Debug)]
pub struct CausalBatch {
    pub x: Matrix,
    pub labels: Vec<usize>,
}

/// Next-token-prediction batches over a [`Corpus`] for the causal
/// transformer proxy (`charlm` task): each sample is a fresh length
/// `seq_len+1` sequence — the first `seq_len` tokens are input, positions
/// shifted by one are the targets.
pub struct CausalLmBatchGen {
    corpus: Corpus,
    pub seq_len: usize,
    rng: Rng,
}

impl CausalLmBatchGen {
    pub fn new(cfg: TextConfig, seq_len: usize, seed: u64) -> Self {
        CausalLmBatchGen { corpus: Corpus::new(cfg), seq_len, rng: Rng::new(seed ^ 0xCA5A1) }
    }

    pub fn vocab(&self) -> usize {
        self.corpus.vocab()
    }

    /// Next batch of `b` sequences.
    pub fn next_batch(&mut self, b: usize) -> CausalBatch {
        let s = self.seq_len;
        let mut x = Matrix::zeros(s, b);
        let mut labels = Vec::with_capacity(b * s);
        for j in 0..b {
            let seq = self.corpus.sample_sequence(s + 1, &mut self.rng);
            for t in 0..s {
                x[(t, j)] = seq[t] as f32;
                labels.push(seq[t + 1] as usize);
            }
        }
        CausalBatch { x, labels }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequences_are_deterministic_per_seed() {
        let c1 = Corpus::new(TextConfig::default());
        let c2 = Corpus::new(TextConfig::default());
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(1);
        assert_eq!(c1.sample_sequence(64, &mut r1), c2.sample_sequence(64, &mut r2));
    }

    #[test]
    fn unigram_distribution_is_skewed() {
        let c = Corpus::new(TextConfig { vocab: 256, ..Default::default() });
        let mut rng = Rng::new(2);
        let mut counts = vec![0usize; 256];
        for _ in 0..200 {
            for t in c.sample_sequence(128, &mut rng) {
                counts[t as usize] += 1;
            }
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let top10: usize = counts[..10].iter().sum();
        let total: usize = counts.iter().sum();
        // Zipf(1.1) over 256 symbols concentrates far more than uniform
        // (uniform would put ~3.9% in the top 10).
        assert!(top10 as f64 / total as f64 > 0.15, "top10 frac {}", top10 as f64 / total as f64);
    }

    #[test]
    fn mlm_masks_are_marked() {
        let mut g = MlmBatchGen::new(TextConfig::default(), 32, 0.15, 3);
        let b = g.next_tokens(4);
        assert_eq!(b.tokens.len(), 4);
        for (ts, gs) in b.tokens.iter().zip(&b.targets) {
            let masked = gs.iter().filter(|&&x| x != u32::MAX).count();
            assert!(masked >= 1);
            for (t, g) in ts.iter().zip(gs) {
                if *g != u32::MAX {
                    assert_eq!(*t, 1023); // mask_id = vocab-1
                }
            }
        }
        let (toks, tgts, mask) = b.to_flat();
        assert_eq!(toks.len(), 4 * 32);
        assert_eq!(tgts.len(), toks.len());
        let nmask: f32 = mask.iter().sum();
        assert!(nmask >= 4.0);
    }

    #[test]
    fn causal_batches_align_labels_with_the_shifted_sequence() {
        let cfg = TextConfig { vocab: 48, ..Default::default() };
        let mut g = CausalLmBatchGen::new(cfg.clone(), 16, 7);
        let b = g.next_batch(3);
        assert_eq!((b.x.rows(), b.x.cols()), (16, 3));
        assert_eq!(b.labels.len(), 3 * 16, "one target per unrolled position");
        for j in 0..3 {
            for t in 0..15 {
                // labels[j·s+t] is the token the model sees at (t+1, j):
                // next-token prediction, in output-column order.
                assert_eq!(b.labels[j * 16 + t], b.x[(t + 1, j)] as usize);
            }
            assert!(b.labels[j * 16 + 15] < 48, "final target drawn from the vocab");
        }
        // Deterministic per seed.
        let mut g2 = CausalLmBatchGen::new(cfg, 16, 7);
        let b2 = g2.next_batch(3);
        assert_eq!(b.x.data(), b2.x.data());
        assert_eq!(b.labels, b2.labels);
    }

    #[test]
    fn dense_batches_shaped_and_normalized() {
        let mut g = MlmBatchGen::new(TextConfig::default(), 64, 0.15, 4);
        let b = g.next_dense(8, 100, 5);
        assert_eq!(b.x.rows(), 100);
        assert_eq!(b.x.cols(), 8);
        assert_eq!(b.labels.len(), 8);
        for col in 0..8 {
            let s: f32 = (0..100).map(|i| b.x[(i, col)]).sum();
            assert!((s - 1.0).abs() < 1e-4 || s == 0.0, "col sum {s}");
            assert!(b.labels[col] < 1024);
        }
    }
}
