//! Template-plus-noise "image" data — CIFAR/ImageNet stand-ins for the
//! autoencoder (Figure 4) and CNN-proxy (Figure 6, Table 5, Figures 11/12c)
//! experiments.
//!
//! Samples are mixtures of a small dictionary of smooth 2-D templates plus
//! pixel noise: like natural images they are compressible (an autoencoder
//! can reduce reconstruction loss far below the noise-free input variance)
//! and class-structured (a classifier proxy can exceed chance by a large
//! margin), while the covariance of activations stays low-rank.

use crate::data::{Batch, DenseBatch};
use crate::linalg::Matrix;
use crate::util::Rng;

/// Synthetic image dataset config.
#[derive(Clone, Debug)]
pub struct ImageConfig {
    /// Image edge; samples are side×side flattened to side².
    pub side: usize,
    pub classes: usize,
    /// Templates per class.
    pub templates_per_class: usize,
    pub noise: f32,
    pub seed: u64,
}

impl Default for ImageConfig {
    fn default() -> Self {
        ImageConfig { side: 16, classes: 10, templates_per_class: 3, noise: 0.25, seed: 0 }
    }
}

/// Streamed generator (no materialized dataset needed for the convergence
/// experiments, which draw fresh batches each step like the paper's
/// large-corpus settings).
pub struct ImageGen {
    cfg: ImageConfig,
    /// `templates[c][k]` is a flattened side² template.
    templates: Vec<Vec<Vec<f32>>>,
    rng: Rng,
}

impl ImageGen {
    pub fn new(cfg: ImageConfig, seed: u64) -> Self {
        let mut trng = Rng::new(cfg.seed ^ 0x1A2B3C);
        let d = cfg.side * cfg.side;
        let mut templates = Vec::with_capacity(cfg.classes);
        for _ in 0..cfg.classes {
            let mut per_class = Vec::with_capacity(cfg.templates_per_class);
            for _ in 0..cfg.templates_per_class {
                per_class.push(smooth_template(cfg.side, &mut trng));
            }
            templates.push(per_class);
        }
        debug_assert!(templates.iter().all(|t| t.iter().all(|v| v.len() == d)));
        ImageGen { cfg, templates, rng: Rng::new(seed ^ 0x99AA) }
    }

    pub fn dim(&self) -> usize {
        self.cfg.side * self.cfg.side
    }

    pub fn classes(&self) -> usize {
        self.cfg.classes
    }

    /// Draw one sample; returns (pixels, class).
    fn sample(&mut self) -> (Vec<f32>, usize) {
        let c = self.rng.next_below(self.cfg.classes as u64) as usize;
        let k = self.rng.next_below(self.cfg.templates_per_class as u64) as usize;
        let amp = 0.6 + 0.8 * self.rng.next_f32();
        let mut px: Vec<f32> = self.templates[c][k].iter().map(|&t| amp * t).collect();
        for p in px.iter_mut() {
            *p += self.rng.gaussian_f32() * self.cfg.noise;
        }
        (px, c)
    }

    /// Classification batch (Figure 6 / Table 5 proxies).
    pub fn next_batch(&mut self, b: usize) -> Batch {
        let d = self.dim();
        let mut x = Matrix::zeros(d, b);
        let mut labels = Vec::with_capacity(b);
        for col in 0..b {
            let (px, c) = self.sample();
            for (i, &v) in px.iter().enumerate() {
                x[(i, col)] = v;
            }
            labels.push(c);
        }
        Batch { x, labels }
    }

    /// Autoencoder batch: targets are the *clean* template mixtures, so the
    /// optimum is denoising and the loss floor is the noise variance.
    pub fn next_autoencoder_batch(&mut self, b: usize) -> DenseBatch {
        let d = self.dim();
        let mut x = Matrix::zeros(d, b);
        let mut y = Matrix::zeros(d, b);
        for col in 0..b {
            let c = self.rng.next_below(self.cfg.classes as u64) as usize;
            let k = self.rng.next_below(self.cfg.templates_per_class as u64) as usize;
            let amp = 0.6 + 0.8 * self.rng.next_f32();
            for i in 0..d {
                let clean = amp * self.templates[c][k][i];
                y[(i, col)] = clean;
                x[(i, col)] = clean + self.rng.gaussian_f32() * self.cfg.noise;
            }
        }
        DenseBatch { x, y }
    }
}

/// A smooth random template: sum of a few 2-D cosine modes (low spatial
/// frequency, like the coarse structure of real images).
fn smooth_template(side: usize, rng: &mut Rng) -> Vec<f32> {
    let mut t = vec![0.0f32; side * side];
    let modes = 4;
    for _ in 0..modes {
        let fx = 1.0 + rng.next_below(3) as f32;
        let fy = 1.0 + rng.next_below(3) as f32;
        let phx = rng.next_f32() * std::f32::consts::TAU;
        let phy = rng.next_f32() * std::f32::consts::TAU;
        let amp = rng.gaussian_f32() * 0.5;
        for y in 0..side {
            for x in 0..side {
                let v = amp
                    * ((fx * x as f32 / side as f32) * std::f32::consts::TAU + phx).cos()
                    * ((fy * y as f32 / side as f32) * std::f32::consts::TAU + phy).cos();
                t[y * side + x] += v;
            }
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes() {
        let mut g = ImageGen::new(ImageConfig::default(), 1);
        let b = g.next_batch(12);
        assert_eq!(b.x.rows(), 256);
        assert_eq!(b.x.cols(), 12);
        assert!(b.labels.iter().all(|&c| c < 10));
    }

    #[test]
    fn autoencoder_targets_are_cleaner_than_inputs() {
        let mut g = ImageGen::new(ImageConfig { noise: 0.5, ..Default::default() }, 2);
        let b = g.next_autoencoder_batch(32);
        // x = y + noise ⇒ E‖x−y‖² ≈ d·σ².
        let d = 256.0f64;
        let mut mse = 0.0f64;
        for col in 0..32 {
            for i in 0..256 {
                let e = (b.x[(i, col)] - b.y[(i, col)]) as f64;
                mse += e * e;
            }
        }
        mse /= 32.0 * d;
        assert!((mse - 0.25).abs() < 0.05, "mse={mse}");
    }

    #[test]
    fn classes_are_distinguishable() {
        // Same-class samples correlate more than cross-class on average.
        let mut g = ImageGen::new(ImageConfig { noise: 0.1, ..Default::default() }, 3);
        let b = g.next_batch(200);
        let corr = |i: usize, j: usize| -> f64 {
            let (mut num, mut ni, mut nj) = (0.0f64, 0.0f64, 0.0f64);
            for r in 0..256 {
                let a = b.x[(r, i)] as f64;
                let c = b.x[(r, j)] as f64;
                num += a * c;
                ni += a * a;
                nj += c * c;
            }
            num / (ni.sqrt() * nj.sqrt() + 1e-12)
        };
        let (mut same, mut same_n, mut diff, mut diff_n) = (0.0, 0, 0.0, 0);
        for i in 0..60 {
            for j in (i + 1)..60 {
                let c = corr(i, j).abs();
                if b.labels[i] == b.labels[j] {
                    same += c;
                    same_n += 1;
                } else {
                    diff += c;
                    diff_n += 1;
                }
            }
        }
        let same = same / same_n.max(1) as f64;
        let diff = diff / diff_n.max(1) as f64;
        assert!(same > diff, "same={same} diff={diff}");
    }

    #[test]
    fn deterministic_templates() {
        let mut a = ImageGen::new(ImageConfig::default(), 9);
        let mut b = ImageGen::new(ImageConfig::default(), 9);
        let ba = a.next_batch(4);
        let bb = b.next_batch(4);
        assert_eq!(ba.x.max_abs_diff(&bb.x), 0.0);
    }
}
