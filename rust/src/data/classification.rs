//! Gaussian-mixture classification tasks — the GLUE / fine-tuning proxies.
//!
//! Each task draws class means on a sphere of radius `separation` inside an
//! `intrinsic_rank`-dimensional subspace of the `dim`-dimensional input
//! space, then adds isotropic noise. Low `intrinsic_rank` reproduces the
//! low-rank activation-covariance regime the paper leans on (§4); low
//! `separation` makes a task "hard" (the RTE/CoLA proxies), high makes it
//! "easy" (SST-2 proxy). Fixed train/test splits make accuracy comparable
//! across optimizers.

use crate::data::Batch;
use crate::linalg::{ops, Matrix};
use crate::util::Rng;

/// Task recipe.
#[derive(Clone, Debug)]
pub struct TaskConfig {
    pub name: String,
    pub dim: usize,
    pub classes: usize,
    /// Dimension of the subspace class structure lives in (≤ dim).
    pub intrinsic_rank: usize,
    /// Distance scale between class means (higher = easier).
    pub separation: f32,
    /// Observation noise sigma.
    pub noise: f32,
    pub train: usize,
    pub test: usize,
    pub seed: u64,
}

impl TaskConfig {
    pub fn new(name: &str, dim: usize, classes: usize) -> Self {
        TaskConfig {
            name: name.to_string(),
            dim,
            classes,
            intrinsic_rank: dim / 4,
            separation: 2.0,
            noise: 1.0,
            train: 2048,
            test: 512,
            seed: 0,
        }
    }
}

/// A materialized dataset with fixed splits.
pub struct Dataset {
    pub cfg: TaskConfig,
    pub train_x: Matrix,
    pub train_y: Vec<usize>,
    pub test_x: Matrix,
    pub test_y: Vec<usize>,
}

impl Dataset {
    /// Generate the dataset from its config (deterministic in `cfg.seed`).
    pub fn generate(cfg: TaskConfig) -> Self {
        assert!(cfg.intrinsic_rank >= 1 && cfg.intrinsic_rank <= cfg.dim);
        let mut rng = Rng::new(cfg.seed ^ 0x5EED);
        // Basis of the intrinsic subspace: dim × rank, random Gaussian
        // (approximately orthogonal columns at these scales).
        let basis =
            Matrix::randn(cfg.dim, cfg.intrinsic_rank, 1.0 / (cfg.dim as f32).sqrt(), &mut rng);
        // Class means inside the subspace.
        let mut means = Vec::with_capacity(cfg.classes);
        for _ in 0..cfg.classes {
            let z: Vec<f32> = (0..cfg.intrinsic_rank)
                .map(|_| rng.gaussian_f32() * cfg.separation)
                .collect();
            means.push(ops::matvec(&basis, &z));
        }

        let mut sample_split = |n: usize, rng: &mut Rng| -> (Matrix, Vec<usize>) {
            let mut x = Matrix::zeros(cfg.dim, n);
            let mut y = Vec::with_capacity(n);
            for col in 0..n {
                let c = rng.next_below(cfg.classes as u64) as usize;
                y.push(c);
                // Low-rank within-class variation + isotropic noise.
                let z: Vec<f32> = (0..cfg.intrinsic_rank).map(|_| rng.gaussian_f32()).collect();
                let within = ops::matvec(&basis, &z);
                for i in 0..cfg.dim {
                    x[(i, col)] = means[c][i] + within[i] + rng.gaussian_f32() * cfg.noise;
                }
            }
            (x, y)
        };

        let (train_x, train_y) = sample_split(cfg.train, &mut rng);
        let (test_x, test_y) = sample_split(cfg.test, &mut rng);
        Dataset { cfg, train_x, train_y, test_x, test_y }
    }

    /// Iterate train batches in a shuffled epoch order.
    pub fn epoch_batches(&self, batch: usize, epoch_seed: u64) -> Vec<Batch> {
        let n = self.train_y.len();
        let mut rng = Rng::new(self.cfg.seed ^ epoch_seed.wrapping_mul(0x9E37));
        let perm = rng.permutation(n);
        let mut out = Vec::new();
        let mut i = 0;
        while i < n {
            let b = batch.min(n - i);
            let mut x = Matrix::zeros(self.cfg.dim, b);
            let mut labels = Vec::with_capacity(b);
            for (col, &idx) in perm[i..i + b].iter().enumerate() {
                for r in 0..self.cfg.dim {
                    x[(r, col)] = self.train_x[(r, idx)];
                }
                labels.push(self.train_y[idx]);
            }
            out.push(Batch { x, labels });
            i += b;
        }
        out
    }

    /// Test set as one batch.
    pub fn test_batch(&self) -> Batch {
        Batch { x: self.test_x.clone(), labels: self.test_y.clone() }
    }
}

/// The eight GLUE proxy tasks, difficulty-graded to mirror the paper's
/// per-task metric spread (Table 4: SST-2 easiest ~0.92, CoLA hardest ~0.5).
pub fn glue_proxy_suite(dim: usize, seed: u64) -> Vec<TaskConfig> {
    let mk = |name: &str, classes: usize, sep: f32, rank_frac: f64, i: u64| {
        let mut c = TaskConfig::new(name, dim, classes);
        c.separation = sep;
        c.intrinsic_rank = ((dim as f64 * rank_frac) as usize).max(2);
        c.seed = seed ^ (i * 0x1234_5678);
        c
    };
    vec![
        mk("mnli-proxy", 3, 1.6, 0.25, 1),
        mk("qqp-proxy", 2, 1.8, 0.25, 2),
        mk("qnli-proxy", 2, 2.0, 0.25, 3),
        mk("sst2-proxy", 2, 2.6, 0.25, 4),
        mk("cola-proxy", 2, 0.9, 0.15, 5),
        mk("stsb-proxy", 5, 1.9, 0.25, 6),
        mk("mrpc-proxy", 2, 1.7, 0.2, 7),
        mk("rte-proxy", 2, 1.1, 0.15, 8),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = Dataset::generate(TaskConfig::new("t", 16, 3));
        let b = Dataset::generate(TaskConfig::new("t", 16, 3));
        assert_eq!(a.train_x.max_abs_diff(&b.train_x), 0.0);
        assert_eq!(a.train_y, b.train_y);
    }

    #[test]
    fn shapes_and_label_range() {
        let mut cfg = TaskConfig::new("t", 20, 4);
        cfg.train = 100;
        cfg.test = 30;
        let d = Dataset::generate(cfg);
        assert_eq!(d.train_x.rows(), 20);
        assert_eq!(d.train_x.cols(), 100);
        assert_eq!(d.test_x.cols(), 30);
        assert!(d.train_y.iter().all(|&y| y < 4));
    }

    #[test]
    fn epoch_batches_cover_all_samples() {
        let mut cfg = TaskConfig::new("t", 8, 2);
        cfg.train = 70;
        let d = Dataset::generate(cfg);
        let batches = d.epoch_batches(32, 1);
        let total: usize = batches.iter().map(|b| b.batch_size()).sum();
        assert_eq!(total, 70);
        assert_eq!(batches.len(), 3); // 32 + 32 + 6
        assert_eq!(batches[2].batch_size(), 6);
    }

    #[test]
    fn higher_separation_is_linearly_easier() {
        // Nearest-class-mean accuracy should be much better on an easy task.
        let acc = |sep: f32| -> f64 {
            let mut cfg = TaskConfig::new("t", 24, 3);
            cfg.separation = sep;
            cfg.train = 400;
            cfg.test = 400;
            let d = Dataset::generate(cfg);
            // Estimate class means from train.
            let mut means = vec![vec![0.0f32; 24]; 3];
            let mut counts = [0usize; 3];
            for i in 0..400 {
                let c = d.train_y[i];
                counts[c] += 1;
                for r in 0..24 {
                    means[c][r] += d.train_x[(r, i)];
                }
            }
            for c in 0..3 {
                for v in means[c].iter_mut() {
                    *v /= counts[c].max(1) as f32;
                }
            }
            let mut correct = 0;
            for i in 0..400 {
                let mut best = (f32::INFINITY, 0usize);
                for (c, mean) in means.iter().enumerate() {
                    let d2: f32 = (0..24)
                        .map(|r| (d.test_x[(r, i)] - mean[r]).powi(2))
                        .sum();
                    if d2 < best.0 {
                        best = (d2, c);
                    }
                }
                if best.1 == d.test_y[i] {
                    correct += 1;
                }
            }
            correct as f64 / 400.0
        };
        let easy = acc(3.0);
        let hard = acc(0.3);
        assert!(easy > hard + 0.15, "easy={easy} hard={hard}");
    }

    #[test]
    fn glue_suite_has_eight_distinct_tasks() {
        let suite = glue_proxy_suite(32, 7);
        assert_eq!(suite.len(), 8);
        let names: std::collections::BTreeSet<_> = suite.iter().map(|t| t.name.clone()).collect();
        assert_eq!(names.len(), 8);
    }
}
