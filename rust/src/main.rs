//! `mkor` — the leader binary.
//!
//! Subcommands:
//!
//! * `train`  — end-to-end transformer training through the artifact
//!              runtime (`mkor artifacts` generates the preset bundles).
//!              Flags: `--preset tiny|small|base`,
//!              `--steps N`, `--workers W`, `--lr`, `--inv-freq`,
//!              `--hybrid`, `--out results/e2e.json`.
//! * `sim`    — proxy-model training with any optimizer spec
//!              (`--optimizer name[:key=val,...]`, e.g. `--optimizer
//!              mkor:f=10,backend=lamb,backend.beta1=0.95`; names:
//!              mkor|mkor-h|kfac|sngd|eva|sgd|adam|lamb), `--task
//!              glue|images|autoencoder|text|charlm` (charlm trains the
//!              causal-transformer proxy), `--steps`, `--workers`,
//!              `--eval-every`, `--target`, `--quantized`. Checkpointing:
//!              `--checkpoint-every N --checkpoint-dir D` snapshots every
//!              N steps; `--resume-from D` restores and continues
//!              bitwise-identically (run the same flags); `--keep-every N`
//!              retains step-stamped `step-<t>/` snapshots and
//!              `--keep-best K` prunes them to the K best eval metrics.
//! * `sweep`  — fan a grid of specs out and merge the results into one
//!              CSV/JSON artifact: `--specs
//!              "mkor:f={1,10,100};lamb;kfac:damping={0.01,0.1}"`,
//!              `--task`, `--steps`, `--jobs`, `--out sweep.csv`. Braced
//!              keys cross-multiply; ` x seed=0..4` repeats per seed; `lr`
//!              and `seed` are reserved harness axes (README has the full
//!              grammar). `--jobs J` fans out over an in-process thread
//!              pool; `--workers N` fans out over N crash-isolated
//!              `sweep-worker` subprocesses instead (`--worker-batch B`
//!              cells per dispatch, `--worker-dir D` scratch directory,
//!              default `<out>.workers/`; `--cell-workers W` sets the
//!              simulated data-parallel workers *inside* each cell).
//!              `--resume` reloads `--out` — plus, with `--workers`, any
//!              leftover worker result files — and re-runs only the
//!              missing cells of an interrupted grid.
//!              `--checkpoint-every N --checkpoint-dir D` snapshots every
//!              cell into `D/cell-<index>` so interrupted cells resume
//!              mid-run.
//! * `sweep-worker` — internal: runs one cell batch for `sweep --workers`
//!              (`--cells-json batch.json --out results.jsonl`).
//! * `ckpt`   — `ckpt inspect <dir>` prints a checkpoint's manifest
//!              (step, spec, task, per-component file/hash/bytes) after
//!              validating every blob; `--dump [component]` adds the
//!              `StateDict` contents as JSON.
//! * `perf`   — run the benchmark suite (GEMM GFLOP/s serial vs. engine,
//!              per-optimizer steps/sec, ring all-reduce GB/s) and print a
//!              report. `--quick` for the CI smoke policy, `--json PATH` to
//!              emit the versioned schema, `--threads N` to pin the engine
//!              pool (results never change with N — only speed).
//! * `trace`  — `trace summarize <t.jsonl>` prints the per-phase breakdown
//!              of a `--trace` file (count/total/mean/p50/p99 per event
//!              kind plus share of step time; `--strict` exits non-zero on
//!              a torn tail); `trace cat <t.jsonl>` prints every event as
//!              one line; `trace export <t.jsonl> --chrome out.json`
//!              writes the Chrome trace-event form (load it in
//!              `about:tracing`/Perfetto), `--span-tree` prints the nested
//!              span aggregation; `trace diff BASE NEW [--max-regress
//!              PCT]` compares two traces (or two saved perf reports) and
//!              exits non-zero on a regression past the threshold.
//! * `tail`   — follow a live `--trace` file in place: latest step/loss,
//!              freshest heartbeat, per-kind counts
//!              (`--interval-ms N`, `--for-secs S`, `--once`).
//! * `serve`  — training-as-a-service daemon: accept sweep jobs over a
//!              versioned line-JSON TCP protocol, run them through the
//!              crash-isolated subprocess dispatcher and keep a journaled
//!              queue that survives daemon restarts (`--addr HOST:PORT`,
//!              `--dir D`, `--capacity N`, `--runners N`). README
//!              "Serving" has the protocol and operator guide.
//! * `submit` — client: enqueue one sweep job on a daemon (`--addr`,
//!              sweep-shaped flags, `--wait [--out F --json F]` to poll
//!              to completion and save the byte-identical artifacts).
//! * `jobs`   — client: list a daemon's jobs or `--cancel JOB` a queued
//!              one.
//! * `observe`— client: subscribe to a job's live state + trace stream
//!              (`mkor observe JOB --addr ...`), rendered like `tail`.
//! * `artifacts` — generate the sim-backend preset bundles under
//!              `--out artifacts` (see `rust/src/runtime/sim.rs`).
//! * `specs`  — print the paper-scale model specs and Table-1 complexity.
//! * `version`
//!
//! Every command accepts `--trace PATH` (or `MKOR_TRACE=PATH`) to write a
//! JSONL trace of the run; telemetry never changes artifact bytes.

use mkor::bench_utils::Table;
use mkor::cli::Args;
use mkor::coordinator::{Target, TrainerBuilder};
use mkor::costmodel::complexity::{model_step_cost, OptimizerKind};
use mkor::data::classification::{Dataset, TaskConfig};
use mkor::data::images::{ImageConfig, ImageGen};
use mkor::data::text::{CausalLmBatchGen, MlmBatchGen, TextConfig};
use mkor::experiments::convergence::RunOpts;
use mkor::model::{specs, Activation, Mlp, Model, Transformer, TransformerConfig};
use mkor::obs;
use mkor::optim::OptimizerSpec;
use mkor::runtime::xla_trainer::{XlaTrainer, XlaTrainerConfig};
use mkor::runtime::ArtifactBundle;
use mkor::checkpoint::{Checkpoint, MANIFEST_FILE};
use mkor::sweep::{
    run_sweep_mp, run_sweep_resumed, run_worker, task_by_name, MpOptions, SweepGrid,
    SweepOptions, SweepReport,
};
use mkor::util::json::Json;
use mkor::util::Rng;
use std::path::{Path, PathBuf};

fn main() {
    mkor::util::logging::init_from_env();
    let args = Args::from_env();
    let cmd = args.command();
    // `--trace PATH` installs the process-global JSONL sink before the
    // command runs; MKOR_TRACE is the env fallback. The `trace` and
    // `tail` reader subcommands never trace themselves.
    if cmd != Some("trace") && cmd != Some("tail") {
        if let Some(path) = args.get("trace") {
            if let Err(e) = obs::install(Path::new(path)) {
                eprintln!("error: --trace: {e:#}");
                std::process::exit(2);
            }
        } else {
            obs::sink::init_from_env();
        }
    }
    let code = match cmd {
        Some("version") => {
            println!("mkor {}", mkor::VERSION);
            0
        }
        Some("specs") => cmd_specs(),
        Some("perf") => cmd_perf(&args),
        Some("sim") => cmd_sim(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("sweep-worker") => cmd_sweep_worker(&args),
        Some("ckpt") => cmd_ckpt(&args),
        Some("train") => cmd_train(&args),
        Some("trace") => cmd_trace(&args),
        Some("tail") => cmd_tail(&args),
        Some("serve") => mkor::serve::commands::cmd_serve(&args),
        Some("submit") => mkor::serve::commands::cmd_submit(&args),
        Some("jobs") => mkor::serve::commands::cmd_jobs(&args),
        Some("observe") => mkor::serve::commands::cmd_observe(&args),
        Some("artifacts") => mkor::serve::commands::cmd_artifacts(&args),
        _ => {
            eprintln!(
                "usage: mkor <train|sim|sweep|ckpt|serve|submit|jobs|observe|artifacts|perf|\
                 trace|tail|specs|version> [--flags]\n\
                 see README.md for details"
            );
            2
        }
    };
    // Unconditional teardown: a no-op when no sink was installed.
    match obs::finish() {
        Some(Ok(receipt)) => {
            obs::log::note(&format!(
                "trace: {} events -> {}",
                receipt.events,
                receipt.path.display()
            ));
        }
        Some(Err(e)) => eprintln!("trace: {e:#}"),
        None => {}
    }
    std::process::exit(code);
}

/// `mkor trace <summarize|cat|export|diff> ...`: decode `--trace` files
/// back through the validating reader and aggregate, dump, export or
/// compare them. Results print to stdout; progress notes and warnings go
/// through [`obs::log`], so `MKOR_LOG=quiet` leaves only the results.
fn cmd_trace(args: &Args) -> i32 {
    let usage = || {
        eprintln!(
            "usage: mkor trace summarize <trace.jsonl> [--strict]\n\
             \x20      mkor trace cat <trace.jsonl>\n\
             \x20      mkor trace export <trace.jsonl> [--chrome out.json] [--span-tree]\n\
             \x20      mkor trace diff <base> <new> [--max-regress PCT]"
        );
    };
    let Some(action) = args.positional.get(1).map(String::as_str) else {
        usage();
        return 2;
    };
    if action == "diff" {
        return cmd_trace_diff(args);
    }
    let Some(path) = args.positional.get(2) else {
        usage();
        return 2;
    };
    let log = match obs::read_trace(Path::new(path)) {
        Ok(log) => log,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    if log.torn_tail {
        obs::log::warn("warning: skipped a torn final line (the writer died mid-write)");
        // Version skew is already fatal in read_trace; --strict upgrades
        // the only tolerated defect too, for CI gates on archived traces.
        if args.flag("strict") {
            eprintln!("error: --strict: trace has a torn tail");
            return 1;
        }
    }
    match action {
        "summarize" => {
            obs::log::note(&format!("{path}: {} events", log.events.len()));
            print!("{}", obs::TraceSummary::from_events(&log.events).render());
            0
        }
        "cat" => {
            for ev in &log.events {
                println!("{}", ev.render());
            }
            0
        }
        "export" => {
            let mut exported = false;
            if let Some(out) = args.get("chrome") {
                let chrome = obs::chrome_trace_json(&log.events);
                if let Err(e) = chrome.to_file(Path::new(out)) {
                    eprintln!("saving {out}: {e:#}");
                    return 1;
                }
                obs::log::note(&format!("wrote {out} (load in about:tracing or Perfetto)"));
                exported = true;
            }
            if args.flag("span-tree") {
                print!("{}", obs::render_span_tree(&log.events));
                exported = true;
            }
            if !exported {
                eprintln!("error: export needs --chrome OUT and/or --span-tree");
                return 2;
            }
            0
        }
        _ => {
            usage();
            2
        }
    }
}

/// The `diff` half of [`cmd_trace`]: compare two runs and exit non-zero
/// when any shared metric regressed past `--max-regress` percent
/// (default 50). Inputs are two traces or two saved perf reports; a
/// negative threshold fails on any non-improvement (CI passes
/// `--max-regress -100` to prove the gate trips).
fn cmd_trace_diff(args: &Args) -> i32 {
    let (Some(base), Some(new)) = (args.positional.get(2), args.positional.get(3)) else {
        eprintln!("usage: mkor trace diff <base> <new> [--max-regress PCT]");
        return 2;
    };
    let max_regress = args.f64_or("max-regress", 50.0);
    // A perf report is one JSON object carrying `schema_version`; a trace
    // is JSONL (one event object per line). Both sides must be the same
    // shape for the comparison to mean anything.
    let as_report =
        |p: &str| Json::from_file(Path::new(p)).ok().filter(|j| j.get("schema_version").is_some());
    let diff = match (as_report(base), as_report(new)) {
        (Some(b), Some(n)) => {
            let parse = |j: &Json, path: &str| match mkor::perf::PerfReport::from_json(j) {
                Ok(report) => Some(report),
                Err(e) => {
                    eprintln!("error: {path}: {e:#}");
                    None
                }
            };
            let (Some(b), Some(n)) = (parse(&b, base), parse(&n, new)) else {
                return 1;
            };
            obs::TraceDiff::of_reports(&b, &n)
        }
        (None, None) => {
            let read = |path: &str| match obs::read_trace(Path::new(path)) {
                Ok(log) => Some(log.events),
                Err(e) => {
                    eprintln!("error: {path}: {e:#}");
                    None
                }
            };
            let (Some(b), Some(n)) = (read(base), read(new)) else {
                return 1;
            };
            obs::TraceDiff::of_traces(&b, &n)
        }
        _ => {
            eprintln!("error: cannot diff a perf report against a trace");
            return 2;
        }
    };
    print!("{}", diff.render());
    let bad = diff.regressions(max_regress);
    if bad.is_empty() {
        obs::log::note(&format!(
            "no regression beyond {max_regress}% across {} shared metrics",
            diff.rows.len()
        ));
        return 0;
    }
    for row in &bad {
        obs::log::warn(&format!("regressed: {} ({:+.1}%)", row.name, row.delta_pct));
    }
    eprintln!(
        "error: {} of {} shared metrics regressed beyond {max_regress}%",
        bad.len(),
        diff.rows.len()
    );
    1
}

/// `mkor tail <trace.jsonl> [--interval-ms N] [--for-secs S] [--once]`:
/// follow a live `--trace` file, rendering an aggregated view in place
/// (latest step/loss, freshest heartbeat payload, per-kind counts). A
/// file that does not exist yet and a torn tail both just wait — start
/// the tail before or after the run. Runs until interrupted unless
/// `--for-secs` bounds it (`--once` renders a single frame and exits).
fn cmd_tail(args: &Args) -> i32 {
    use std::io::{IsTerminal, Write};
    let Some(path) = args.positional.get(1) else {
        eprintln!("usage: mkor tail <trace.jsonl> [--interval-ms N] [--for-secs S] [--once]");
        return 2;
    };
    let interval = std::time::Duration::from_millis(args.u64_or("interval-ms", 500));
    let for_secs = args.f64_or("for-secs", f64::INFINITY);
    let once = args.flag("once");
    let mut follower = obs::TraceFollower::new(Path::new(path));
    let mut view = obs::TailView::default();
    // In-place redraw only on a real terminal; under a pipe (CI) each
    // frame appends, keeping the output a plain readable log.
    let ansi = std::io::stdout().is_terminal();
    let t0 = std::time::Instant::now();
    let mut drawn_lines = 0usize;
    loop {
        for ev in follower.poll() {
            view.absorb(&ev);
        }
        let screen = view.render();
        {
            let mut out = std::io::stdout().lock();
            if ansi && drawn_lines > 0 {
                let _ = write!(out, "\x1b[{drawn_lines}A\x1b[J");
            }
            let _ = out.write_all(screen.as_bytes());
            let _ = out.flush();
        }
        drawn_lines = screen.lines().count();
        if once || t0.elapsed().as_secs_f64() >= for_secs {
            return 0;
        }
        std::thread::sleep(interval);
    }
}

fn cmd_specs() -> i32 {
    let mut t = Table::new(&["Model", "Params (M)", "Max dim d", "Eff. batch b"]);
    for name in ["bert-large", "bert-base", "resnet50", "alexnet", "autoencoder"] {
        let s = specs::by_name(name).unwrap();
        t.row(&[
            s.name.clone(),
            format!("{:.1}", s.params() as f64 / 1e6),
            s.max_dim().to_string(),
            s.effective_batch.to_string(),
        ]);
    }
    println!("{}", t.render());

    let spec = specs::bert_large();
    let mut t = Table::new(&["Optimizer", "Factor FLOPs", "Sync bytes", "State bytes"]);
    for kind in [
        OptimizerKind::Mkor,
        OptimizerKind::Kfac,
        OptimizerKind::Sngd,
        OptimizerKind::Eva,
        OptimizerKind::Lamb,
    ] {
        let c = model_step_cost(kind, &spec);
        t.row(&[
            kind.label().to_string(),
            format!("{:.2e}", c.factor_flops),
            format!("{:.2e}", c.sync_bytes),
            format!("{:.2e}", c.state_bytes),
        ]);
    }
    println!("BERT-Large per-step costs (Table 1 instantiated):");
    println!("{}", t.render());
    0
}

/// `mkor perf [--quick] [--json PATH] [--threads N]`: run the benchmark
/// suite (README "Measuring performance") and optionally emit the
/// versioned JSON report — `BENCH_mkor.json` is a committed instance.
fn cmd_perf(args: &Args) -> i32 {
    let quick = args.flag("quick");
    let threads = args.usize_or("threads", mkor::linalg::engine::hw_threads());
    if threads == 0 {
        eprintln!("error: --threads must be at least 1");
        return 2;
    }
    obs::log::progress(&format!(
        "running perf suite ({} policy, {threads} threads)...",
        if quick { "quick" } else { "full" }
    ));
    let mut report = mkor::perf::run_suite(quick, threads);
    // Record where this run's trace went (if anywhere) so a saved report
    // points at its own phase-level evidence.
    if obs::enabled() {
        report.trace =
            args.get("trace").map(str::to_string).or_else(|| std::env::var("MKOR_TRACE").ok());
    }
    print!("{}", report.render());
    if let Err(e) = report.validate() {
        eprintln!("error: report failed validation: {e}");
        return 1;
    }
    if let Some(out) = args.get("json") {
        if let Err(e) = report.save(Path::new(out)) {
            eprintln!("saving {out}: {e:#}");
            return 1;
        }
        println!("wrote {out}");
    }
    0
}

fn cmd_sim(args: &Args) -> i32 {
    let opt_name = args.get_or("optimizer", "mkor");
    let task = args.get_or("task", "glue");
    let steps = args.usize_or("steps", 300);
    // `--cell-workers` is the sweep-side name for the same knob; accept
    // it here too so recipes move between `sim` and `sweep` unchanged.
    let workers = args.usize_or("cell-workers", args.usize_or("workers", 4));
    let lr = args.f32_or("lr", 0.1);
    let seed = args.u64_or("seed", 0);
    // --target needs evals to be observed; default a cadence in when the
    // user asks for a target but no explicit --eval-every.
    let eval_default = if args.get("target").is_some() { 25 } else { 0 };
    let eval_every = args.usize_or("eval-every", eval_default);

    let mut rng = Rng::new(seed);
    type BatchFn = Box<dyn FnMut() -> (mkor::linalg::Matrix, Target)>;
    let (model, mut next_batch): (Box<dyn Model>, BatchFn) = match task {
        "images" => {
            let mut gen = ImageGen::new(ImageConfig::default(), seed);
            let model =
                Mlp::new(&[gen.dim(), 128, 64, gen.classes()], Activation::Relu, &mut rng);
            (
                Box::new(model),
                Box::new(move || {
                    let b = gen.next_batch(64);
                    (b.x, Target::Labels(b.labels))
                }),
            )
        }
        "autoencoder" => {
            let mut gen = ImageGen::new(ImageConfig::default(), seed);
            let d = gen.dim();
            let model = Mlp::new(&[d, 128, 32, 128, d], Activation::Tanh, &mut rng);
            (
                Box::new(model),
                Box::new(move || {
                    let b = gen.next_autoencoder_batch(64);
                    (b.x, Target::Dense(b.y))
                }),
            )
        }
        "text" => {
            let mut gen = MlmBatchGen::new(TextConfig::default(), 64, 0.15, seed);
            let vocab = gen.vocab();
            let model = Mlp::new(&[256, 256, vocab], Activation::Gelu, &mut rng);
            (
                Box::new(model),
                Box::new(move || {
                    let b = gen.next_dense(64, 256, 6);
                    (b.x, Target::Labels(b.labels))
                }),
            )
        }
        "charlm" => {
            // Causal-transformer proxy: 16-token next-token prediction on
            // the Markov–Zipf corpus; 16 sequences per batch unroll to 256
            // capture columns.
            let mut gen = CausalLmBatchGen::new(
                TextConfig { vocab: 48, seed, ..Default::default() },
                16,
                seed,
            );
            let model =
                Transformer::new(TransformerConfig::proxy(gen.vocab(), 16), &mut rng);
            (
                Box::new(model),
                Box::new(move || {
                    let b = gen.next_batch(16);
                    (b.x, Target::Labels(b.labels))
                }),
            )
        }
        _ => {
            // "glue": a single representative task.
            let mut cfg = TaskConfig::new("qnli-proxy", 64, 2);
            cfg.seed = seed;
            let ds = Dataset::generate(cfg);
            let model = Mlp::new(&[64, 64, 2], Activation::Relu, &mut rng);
            let mut epoch = 0u64;
            let mut queue: Vec<mkor::data::Batch> = Vec::new();
            (
                Box::new(model),
                Box::new(move || {
                    if queue.is_empty() {
                        queue = ds.epoch_batches(64, epoch);
                        epoch += 1;
                    }
                    let b = queue.pop().unwrap();
                    (b.x, Target::Labels(b.labels))
                }),
            )
        }
    };

    // Parse the optimizer spec up front so a typo reports an actionable
    // message (naming valid optimizers/keys) instead of panicking.
    let spec = match OptimizerSpec::parse(opt_name) {
        Ok(spec) => spec,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    obs::log::progress(&format!("optimizer spec: {}", spec.canonical()));
    let run_name = format!("sim-{task}-{}", spec.canonical());
    let mut builder = TrainerBuilder::new_boxed(model)
        .optimizer(spec)
        .constant_lr(lr)
        .workers(workers)
        .quantized_grads(args.flag("quantized"))
        .run_name(run_name)
        .checkpoint_task(task.to_string());
    if let Some(t) = args.get("target") {
        match t.parse::<f64>() {
            Ok(target) => builder = builder.target_metric(target),
            Err(_) => {
                eprintln!("error: bad --target `{t}`: expected a number");
                return 2;
            }
        }
    }
    let checkpoint_every = args.usize_or("checkpoint-every", 0);
    // Retention rides on checkpointing: --keep-every N stamps step-<t>/
    // subdirectories that later saves never overwrite; --keep-best K
    // prunes them to the K best eval metrics after each retention save.
    let keep_every = args.usize_or("keep-every", 0);
    let keep_best = args.usize_or("keep-best", 0);
    if keep_best > 0 && keep_every == 0 {
        eprintln!("error: --keep-best needs --keep-every (the retention cadence)");
        return 2;
    }
    match args.get("checkpoint-dir") {
        Some(dir) => {
            builder = builder
                .checkpoint_dir(dir)
                .checkpoint_every(checkpoint_every)
                .keep_every(keep_every)
                .keep_best(keep_best);
        }
        None if checkpoint_every > 0 || keep_every > 0 => {
            eprintln!("error: --checkpoint-every/--keep-every need --checkpoint-dir");
            return 2;
        }
        None => {}
    }
    if let Some(dir) = args.get("resume-from") {
        builder = builder.resume_from(dir);
    }
    let mut trainer = match builder.try_build() {
        Ok(trainer) => trainer,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    // Resume: replay the (deterministic) data stream up to the checkpoint
    // step, training only from there — run the same flags as the original
    // run for a bitwise-identical continuation.
    let start = trainer.steps_done();
    if start > 0 {
        obs::log::note(&format!(
            "resumed at step {start} ({} recorded steps)",
            trainer.record.steps.len()
        ));
    }
    // Held-out eval batch (only drawn when evals are requested).
    let eval_batch = if eval_every > 0 { Some(next_batch()) } else { None };
    for s in 0..steps {
        let (x, target) = next_batch();
        if s < start {
            continue; // replayed batch — trained before the checkpoint
        }
        match trainer.step(&x, &target) {
            Some(loss) => {
                if s % 20 == 0 {
                    obs::log::progress(&format!("step {s:>5}  loss {loss:.5}"));
                }
            }
            None => {
                println!("DIVERGED at step {s}");
                break;
            }
        }
        if eval_every > 0 && (s + 1) % eval_every == 0 {
            if let Some((ex, et)) = &eval_batch {
                let (l, acc) = trainer.evaluate(ex, et);
                match acc {
                    Some(a) => obs::log::progress(&format!("  eval acc {a:.4} (loss {l:.5})")),
                    None => obs::log::progress(&format!("  eval loss {l:.5}")),
                }
                if trainer.converged() {
                    obs::log::note(&format!("reached target at step {s}"));
                    trainer.checkpoint_tick();
                    break;
                }
            }
        }
        // After the eval, so a boundary checkpoint carries this step's
        // eval metric in its record.
        trainer.checkpoint_tick();
    }
    let rec = trainer.finish();
    println!(
        "final loss {:.5} over {} steps ({} total comm)",
        rec.final_loss(),
        rec.steps.len(),
        mkor::bench_utils::fmt_bytes(rec.total_comm_bytes() as f64)
    );
    if let Some(out) = args.get("out") {
        if let Err(e) = rec.save_json(Path::new(out)) {
            eprintln!("saving {out}: {e}");
            return 1;
        }
        println!("wrote {out}");
    }
    0
}

fn cmd_sweep(args: &Args) -> i32 {
    let Some(specs) = args.get("specs") else {
        eprintln!(
            "usage: mkor sweep --specs \"mkor:f={{1,10,100}};lamb;kfac:damping={{0.01,0.1}}\" \
             [--task glue|images|autoencoder|text|charlm] [--steps N] [--jobs J] [--lr LR] \
             [--cell-workers W] [--batch B] [--seed S] [--eval-every N] [--target M] \
             [--hidden 96,48] [--out sweep.csv] [--json sweep.json] \
             [--workers N] [--worker-batch B] [--worker-dir D] [--keep-worker-files] \
             [--checkpoint-every N --checkpoint-dir D] \
             [--deterministic] [--resume] [--quiet]\n\
             --jobs fans cells out over an in-process thread pool; --workers N fans \
             them out over N crash-isolated subprocesses instead (byte-identical \
             deterministic artifacts either way)"
        );
        return 2;
    };
    let task = match task_by_name(args.get_or("task", "glue")) {
        Ok(task) => task,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let base_seed = args.u64_or("seed", 0);
    let grid = match SweepGrid::parse(specs, &task, base_seed) {
        Ok(grid) => grid,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };

    let target_metric = match args.get("target") {
        None => None,
        Some(t) => match t.parse::<f64>() {
            Ok(v) => Some(v),
            Err(_) => {
                eprintln!("error: bad --target `{t}`: expected a number");
                return 2;
            }
        },
    };
    let mut run = RunOpts {
        lr: args.f32_or("lr", 0.1),
        steps: args.usize_or("steps", 300),
        workers: args.usize_or("cell-workers", 2),
        batch: args.usize_or("batch", 64),
        seed: base_seed,
        eval_every: args.usize_or("eval-every", 10),
        target_metric,
        ..Default::default()
    };
    if let Some(h) = args.get("hidden") {
        let widths: Result<Vec<usize>, _> =
            h.split(',').map(|w| w.trim().parse::<usize>()).collect();
        match widths {
            Ok(hidden) => run.hidden = hidden,
            Err(_) => {
                eprintln!("error: bad --hidden `{h}`: expected widths like `96,48`");
                return 2;
            }
        }
    }
    // Per-cell checkpointing: every cell snapshots into its own
    // `cell-<index>` subdirectory of --checkpoint-dir and resumes from it
    // when re-run (see SweepOptions::run_for_cell).
    run.checkpoint_every = args.usize_or("checkpoint-every", 0);
    match args.get("checkpoint-dir") {
        Some(dir) => run.checkpoint_dir = Some(PathBuf::from(dir)),
        None if run.checkpoint_every > 0 => {
            eprintln!("error: --checkpoint-every needs --checkpoint-dir");
            return 2;
        }
        None => {}
    }
    let default_jobs = std::thread::available_parallelism().map_or(1, |n| n.get());
    let opts = SweepOptions {
        jobs: args.usize_or("jobs", default_jobs),
        run,
        verbose: !args.flag("quiet"),
    };
    let workers = args.usize_or("workers", 0);
    // `--workers` used to be the per-cell data-parallel width (now
    // `--cell-workers`); surface the repurposing so old invocations are
    // not silently reinterpreted.
    if workers > 0 && args.get("cell-workers").is_none() {
        obs::log::note(&format!(
            "note: --workers now selects the process fan-out ({workers} subprocesses); \
             per-cell data-parallel workers stay at {} (set --cell-workers to change)",
            opts.run.workers
        ));
    }
    if workers > 0 && args.get("jobs").is_some() {
        obs::log::note(&format!(
            "note: --jobs is ignored with --workers: each of the {workers} worker \
             processes runs its cell batch serially"
        ));
    }

    // --resume: reload prior results from --out and skip completed cells
    // (keyed by canonical spec + seed + lr; panicked cells re-run). Run
    // with the same flags as the interrupted sweep so the keys line up.
    let prior = if args.flag("resume") {
        let Some(out) = args.get("out") else {
            eprintln!("error: --resume needs --out (the CSV holding prior results)");
            return 2;
        };
        if out.ends_with(".json") {
            eprintln!("error: --resume reads prior results from a CSV --out");
            return 2;
        }
        let path = Path::new(out);
        if path.is_file() {
            match SweepReport::load_csv(path) {
                Ok(prior) => {
                    obs::log::note(&format!(
                        "resuming: {} prior cells loaded from {out}",
                        prior.cells.len()
                    ));
                    Some(prior)
                }
                Err(e) => {
                    eprintln!("error: loading prior results: {e}");
                    return 2;
                }
            }
        } else {
            None // nothing saved yet: run the full grid
        }
    } else {
        None
    };

    let fan_label = if workers > 0 {
        format!("{workers} worker processes")
    } else {
        format!("{} jobs", opts.jobs)
    };
    obs::log::progress(&format!(
        "sweep: {} cells × {} steps on `{}`, {}",
        grid.len(),
        opts.run.steps,
        args.get_or("task", "glue"),
        fan_label
    ));
    let report = if workers > 0 {
        // Multi-process fan-out: one subprocess per cell batch, results
        // streamed back through the scratch directory and merged in grid
        // order — byte-identical deterministic artifacts to --jobs runs.
        let scratch = match args.get("worker-dir") {
            Some(dir) => PathBuf::from(dir),
            None => match args.get("out") {
                Some(out) => PathBuf::from(format!("{out}.workers")),
                None => std::env::temp_dir().join(format!("mkor-sweep-{}", std::process::id())),
            },
        };
        let mut mp = MpOptions::new(scratch, workers);
        mp.batch = args.usize_or("worker-batch", 0);
        mp.recover = args.flag("resume");
        mp.keep_scratch = args.flag("keep-worker-files");
        match run_sweep_mp(&grid, &opts, &mp, prior.as_ref()) {
            Ok(report) => report,
            Err(e) => {
                eprintln!("error: {e:#}");
                return 1;
            }
        }
    } else {
        run_sweep_resumed(&grid, &opts, prior.as_ref())
    };
    println!("{}", report.render_table());
    let (ok, diverged, panicked) = report.counts();
    let skipped = report.cells.iter().filter(|c| c.skipped).count();
    if skipped > 0 {
        println!("{ok} ok, {diverged} diverged, {panicked} panicked ({skipped} reused)");
    } else {
        println!("{ok} ok, {diverged} diverged, {panicked} panicked");
    }

    // --deterministic drops the wall-clock columns so artifact bytes
    // depend only on the grid and seeds, never on --jobs or machine load.
    let det = args.flag("deterministic");
    if let Some(out) = args.get("out") {
        let path = Path::new(out);
        let res = if out.ends_with(".json") {
            report.save_json_with(path, det)
        } else {
            report.save_csv_with(path, det)
        };
        if let Err(e) = res {
            eprintln!("saving {out}: {e}");
            return 1;
        }
        println!("wrote {out}");
    }
    if let Some(out) = args.get("json") {
        if let Err(e) = report.save_json_with(Path::new(out), det) {
            eprintln!("saving {out}: {e}");
            return 1;
        }
        println!("wrote {out}");
    }
    if panicked > 0 {
        1
    } else {
        0
    }
}

/// Hidden subcommand: the worker half of `mkor sweep --workers N`. Runs
/// one cell batch sequentially and appends one JSON result line per cell
/// to --out; the coordinator streams, merges and (if this process dies)
/// re-dispatches.
fn cmd_sweep_worker(args: &Args) -> i32 {
    let (Some(cells), Some(out)) = (args.get("cells-json"), args.get("out")) else {
        eprintln!(
            "usage: mkor sweep-worker --cells-json batch.json --out results.jsonl\n\
             (internal: launched by `mkor sweep --workers N`)"
        );
        return 2;
    };
    match run_worker(Path::new(cells), Path::new(out)) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("sweep-worker: {e:#}");
            1
        }
    }
}

/// `mkor ckpt inspect <dir> [--dump [component]]`: validate a checkpoint
/// (manifest well-formed, every blob present with a matching content
/// hash) and print what it holds; `--dump` adds the decoded state dicts
/// as JSON (`StateDict::to_json` — human-readable, lossy for display).
fn cmd_ckpt(args: &Args) -> i32 {
    let usage = || eprintln!("usage: mkor ckpt inspect <dir> [--dump [component]]");
    if args.positional.get(1).map(String::as_str) != Some("inspect") {
        usage();
        return 2;
    }
    let Some(dir) = args.positional.get(2) else {
        usage();
        return 2;
    };
    let dir = Path::new(dir);
    // Checkpoint::load re-hashes every component blob, so a clean inspect
    // doubles as an integrity check. The note goes through obs::log so
    // `MKOR_LOG=quiet` leaves only the inspection results on stdout.
    obs::log::progress(&format!("validating checkpoint {} (blobs re-hashed)...", dir.display()));
    let ckpt = match Checkpoint::load(dir) {
        Ok(ckpt) => ckpt,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    println!("checkpoint {}", dir.display());
    println!("  step       {}", ckpt.step);
    println!("  spec       {}", ckpt.spec);
    println!("  optimizer  {}", ckpt.optimizer);
    let task = if ckpt.task.is_empty() { "(unknown)" } else { ckpt.task.as_str() };
    println!("  task       {task}");
    println!("  run_name   {}", ckpt.run_name);
    if let Some(record) = &ckpt.record {
        println!(
            "  record     {} steps, final loss {:.5}{}",
            record.steps.len(),
            record.final_loss(),
            record
                .converged_at
                .map_or(String::new(), |s| format!(", converged at step {s}"))
        );
    }

    // Per-component file/hash/bytes come from the manifest itself (load
    // validates them but keeps only the decoded state).
    let manifest = match Json::from_file(&dir.join(MANIFEST_FILE)) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: re-reading manifest: {e}");
            return 1;
        }
    };
    let mut t = Table::new(&["component", "file", "bytes", "fnv1a64"]);
    if let Some(Json::Obj(components)) = manifest.get("components") {
        for (name, meta) in components {
            t.row(&[
                name.clone(),
                meta.get("file").and_then(Json::as_str).unwrap_or("?").to_string(),
                meta.get("bytes").and_then(Json::as_usize).map_or("?".into(), |b| b.to_string()),
                meta.get("hash").and_then(Json::as_str).unwrap_or("?").to_string(),
            ]);
        }
    }
    println!("{}", t.render());

    match args.get("dump") {
        None => {}
        // Bare `--dump` parses as the flag value "true": dump everything.
        Some("true") => {
            for (name, sd) in &ckpt.components {
                println!("--- {name} ---");
                println!("{:#}", sd.to_json());
            }
        }
        Some(name) => match ckpt.components.get(name) {
            Some(sd) => println!("{:#}", sd.to_json()),
            None => {
                let known: Vec<&str> = ckpt.components.keys().map(String::as_str).collect();
                eprintln!("error: no component `{name}`; checkpoint has: {}", known.join(", "));
                return 1;
            }
        },
    }
    0
}

fn cmd_train(args: &Args) -> i32 {
    let preset = args.get_or("preset", "tiny");
    let steps = args.usize_or("steps", 50);
    // As in `sim`: `--cell-workers` is accepted as a synonym.
    let workers = args.usize_or("cell-workers", args.usize_or("workers", 2));
    let artifacts = args.get_or("artifacts", "artifacts");
    let eval_every = args.usize_or("eval-every", 25);

    let bundle = match ArtifactBundle::load(Path::new(artifacts), preset) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("loading artifacts for `{preset}`: {e:#}\nrun `make artifacts` first");
            return 1;
        }
    };
    println!(
        "loaded preset `{}` on {} ({} params, {} factor pairs)",
        bundle.meta.preset,
        bundle.platform(),
        bundle.meta.params,
        bundle.meta.factor_dims.len()
    );

    // Initialize parameters in Rust (seeded; same init family as model.py).
    let mut rng = Rng::new(args.u64_or("seed", 0));
    let init = mkor::runtime::xla_trainer::init_params(&bundle.meta, &mut rng);

    let cfg = XlaTrainerConfig {
        workers,
        lr: args.f32_or("lr", 0.05),
        momentum: args.f32_or("momentum", 0.9),
        gamma: args.f32_or("gamma", 0.99),
        inv_freq: args.usize_or("inv-freq", 10),
        half_sync: !args.flag("no-half-sync"),
        hybrid_switch_ratio: if args.flag("hybrid") { Some(0.1) } else { None },
        hybrid_switch_beta: args.f64_or("switch-beta", 0.95),
        ..Default::default()
    };
    let mut trainer = XlaTrainer::new(bundle, init, cfg);

    let mut gen = MlmBatchGen::new(
        TextConfig {
            vocab: trainer.bundle.meta.vocab,
            seed: args.u64_or("seed", 0),
            ..Default::default()
        },
        trainer.bundle.meta.seq_len,
        0.15,
        args.u64_or("seed", 0) ^ 1,
    );
    let eval_batch = gen.next_tokens(trainer.bundle.meta.batch);

    let global_batch = trainer.bundle.meta.batch * workers;
    let t0 = std::time::Instant::now();
    for s in 0..steps {
        let batch = gen.next_tokens(global_batch);
        match trainer.step(&batch) {
            Ok(loss) => {
                if s % 5 == 0 {
                    obs::log::progress(&format!("step {s:>5}  loss {loss:.5}"));
                }
            }
            Err(e) => {
                eprintln!("step {s} failed: {e:#}");
                return 1;
            }
        }
        if eval_every > 0 && (s + 1) % eval_every == 0 {
            match trainer.evaluate(&eval_batch) {
                Ok(l) => obs::log::progress(&format!("  eval loss {l:.5}")),
                Err(e) => eprintln!("  eval failed: {e:#}"),
            }
        }
    }
    println!(
        "{} steps in {} ({} /step), switched={:?}",
        steps,
        mkor::bench_utils::fmt_secs(t0.elapsed().as_secs_f64()),
        mkor::bench_utils::fmt_secs(t0.elapsed().as_secs_f64() / steps.max(1) as f64),
        trainer.record.switched_at,
    );
    if let Some(out) = args.get("out") {
        if let Err(e) = trainer.record.save_json(Path::new(out)) {
            eprintln!("saving {out}: {e}");
            return 1;
        }
        println!("wrote {out}");
    }
    0
}
