//! Cross-validation of the two MKOR implementations: the pure-Rust
//! Algorithm 1 (`optim::mkor`) against the AOT artifacts whose factor
//! update and preconditioning are the L1 Pallas kernels.
//!
//! These tests use the `tiny` artifact preset and never skip: a checked-in
//! `artifacts/` bundle (from `mkor artifacts`) is preferred, and when it is
//! missing the sim preset is generated into a temp dir with an explicit
//! NOTE. `MKOR_REQUIRE_ARTIFACTS=1` (set in CI) turns the fallback into a
//! hard failure so the generator path is actually exercised.

use mkor::linalg::{ops, Matrix};
use mkor::optim::Mkor;
use mkor::runtime::artifact::{literal_f32, literal_scalar, ArtifactBundle};
use mkor::util::Rng;
use std::path::Path;

fn load_tiny() -> ArtifactBundle {
    let dir = Path::new("artifacts");
    if dir.join("tiny/meta.json").is_file() {
        return ArtifactBundle::load(dir, "tiny").expect("artifacts/tiny exists but failed to load");
    }
    if std::env::var("MKOR_REQUIRE_ARTIFACTS").ok().as_deref() == Some("1") {
        panic!(
            "MKOR_REQUIRE_ARTIFACTS=1 but artifacts/tiny is missing — \
             run `mkor artifacts` (target/release/mkor artifacts --out artifacts) first"
        );
    }
    eprintln!(
        "NOTE: artifacts/ missing; generating the tiny sim preset in a temp dir \
         (run `mkor artifacts` to use a persistent bundle)"
    );
    // Unique per call: tests in one binary run in parallel and must not
    // race each other's half-written preset files.
    static GEN: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let n = GEN.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let tmp = std::env::temp_dir().join(format!("mkor-artifacts-{}-{n}", std::process::id()));
    mkor::runtime::sim::write_preset(&tmp, "tiny").expect("generating tiny preset");
    ArtifactBundle::load(&tmp, "tiny").expect("loading generated tiny preset")
}

/// Drive the mkor_step artifact with crafted inputs and compare the factor
/// updates + deltas against the Rust implementation, element by element.
#[test]
fn mkor_step_artifact_matches_rust_algorithm() {
    let bundle = load_tiny();
    let meta = &bundle.meta;
    let np = meta.param_shapes.len();
    let nm = meta.factor_dims.len();
    let gamma = 0.95f32;
    let mut rng = Rng::new(42);

    // Random grads / SPD-ish factors / rank-1 vectors.
    let grads: Vec<Vec<f32>> = meta
        .param_shapes
        .iter()
        .map(|s| {
            let n: usize = s.iter().product();
            let mut v = vec![0.0f32; n];
            rng.fill_gaussian(&mut v, 1.0);
            v
        })
        .collect();
    let spd = |d: usize, rng: &mut Rng| -> Matrix { Matrix::rand_spd(d, 0.3, rng) };
    let linvs: Vec<Matrix> =
        meta.factor_dims.iter().map(|&(_, dout)| spd(dout, &mut rng)).collect();
    let rinvs: Vec<Matrix> = meta.factor_dims.iter().map(|&(din, _)| spd(din, &mut rng)).collect();
    let a_vecs: Vec<Vec<f32>> = meta
        .factor_dims
        .iter()
        .map(|&(din, _)| {
            let mut v = vec![0.0f32; din];
            rng.fill_gaussian(&mut v, 1.0);
            v
        })
        .collect();
    let g_vecs: Vec<Vec<f32>> = meta
        .factor_dims
        .iter()
        .map(|&(_, dout)| {
            let mut v = vec![0.0f32; dout];
            rng.fill_gaussian(&mut v, 1.0);
            v
        })
        .collect();

    // --- run the artifact -----------------------------------------------
    let mut args = Vec::new();
    for (g, s) in grads.iter().zip(&meta.param_shapes) {
        let dims: Vec<i64> = s.iter().map(|&d| d as i64).collect();
        args.push(literal_f32(g, &dims).unwrap());
    }
    for (l, &(_, dout)) in linvs.iter().zip(&meta.factor_dims) {
        args.push(literal_f32(l.data(), &[dout as i64, dout as i64]).unwrap());
    }
    for (r, &(din, _)) in rinvs.iter().zip(&meta.factor_dims) {
        args.push(literal_f32(r.data(), &[din as i64, din as i64]).unwrap());
    }
    for (a, &(din, _)) in a_vecs.iter().zip(&meta.factor_dims) {
        args.push(literal_f32(a, &[din as i64]).unwrap());
    }
    for (g, &(_, dout)) in g_vecs.iter().zip(&meta.factor_dims) {
        args.push(literal_f32(g, &[dout as i64]).unwrap());
    }
    args.push(literal_scalar(gamma).unwrap());
    args.push(literal_scalar(1.0).unwrap()); // factor-update flag on
    let out = bundle.mkor_step.run(&args).expect("mkor_step execution");
    assert_eq!(out.len(), np + 2 * nm);

    // --- compare against the Rust Algorithm 1 ----------------------------
    // Factor updates: Eq. 5/6 via Mkor::sm_update.
    let precond_idx: Vec<usize> = {
        // Preconditioned params are the 2-D matmul weights, identified by
        // matching factor dims against the param shapes in order.
        let mut out = Vec::new();
        let mut fi = 0;
        for (i, s) in meta.param_shapes.iter().enumerate() {
            if fi < nm
                && s.len() == 2
                && (s[0], s[1]) == (meta.factor_dims[fi].0, meta.factor_dims[fi].1)
                && i >= 2
            // embed/pos are first and never preconditioned
            {
                out.push(i);
                fi += 1;
            }
        }
        assert_eq!(out.len(), nm, "failed to align factor dims with params");
        out
    };

    for j in 0..nm {
        let (din, dout) = meta.factor_dims[j];
        // Rust factor update.
        let mut l_rust = linvs[j].clone();
        let mut scratch = vec![0.0f32; dout];
        Mkor::sm_update(&mut l_rust, &g_vecs[j], gamma, &mut scratch);
        let l_art = out[np + j].to_vec::<f32>().unwrap();
        let max_diff = l_rust
            .data()
            .iter()
            .zip(&l_art)
            .fold(0.0f32, |m, (&a, &b)| m.max((a - b).abs()));
        assert!(max_diff < 1e-3, "linv[{j}] diverges: {max_diff}");

        let mut r_rust = rinvs[j].clone();
        let mut scratch = vec![0.0f32; din];
        Mkor::sm_update(&mut r_rust, &a_vecs[j], gamma, &mut scratch);
        let r_art = out[np + nm + j].to_vec::<f32>().unwrap();
        let max_diff = r_rust
            .data()
            .iter()
            .zip(&r_art)
            .fold(0.0f32, |m, (&a, &b)| m.max((a - b).abs()));
        assert!(max_diff < 1e-3, "rinv[{j}] diverges: {max_diff}");

        // Delta: rescale(R⁻¹' ∇ L⁻¹') — Rust dense evaluation.
        let i = precond_idx[j];
        let grad = Matrix::from_vec(din, dout, grads[i].clone());
        let raw = ops::matmul(&ops::matmul(&r_rust, &grad), &l_rust);
        let gn = grad.fro_norm();
        let dn = raw.fro_norm();
        let mut want = raw.clone();
        want.scale((gn / dn.max(1e-30)) as f32);
        let got = out[i].to_vec::<f32>().unwrap();
        let max_diff = want
            .data()
            .iter()
            .zip(&got)
            .fold(0.0f32, |m, (&a, &b)| m.max((a - b).abs()));
        let scale = want.max_abs().max(1.0);
        assert!(
            max_diff / scale < 2e-3,
            "delta[{j}] diverges: {max_diff} (scale {scale})"
        );
    }
}

/// flag = 0 must leave the factors untouched and pass preconditioned (but
/// not re-updated) deltas.
#[test]
fn mkor_step_flag_zero_freezes_factors() {
    let bundle = load_tiny();
    let meta = &bundle.meta;
    let np = meta.param_shapes.len();
    let nm = meta.factor_dims.len();
    let mut rng = Rng::new(7);

    let mut args = Vec::new();
    for s in &meta.param_shapes {
        let n: usize = s.iter().product();
        let mut v = vec![0.0f32; n];
        rng.fill_gaussian(&mut v, 1.0);
        let dims: Vec<i64> = s.iter().map(|&d| d as i64).collect();
        args.push(literal_f32(&v, &dims).unwrap());
    }
    let mut idents = Vec::new();
    for &(_, dout) in &meta.factor_dims {
        let m = Matrix::identity(dout);
        idents.push(m.data().to_vec());
        args.push(literal_f32(idents.last().unwrap(), &[dout as i64, dout as i64]).unwrap());
    }
    for &(din, _) in &meta.factor_dims {
        let m = Matrix::identity(din);
        args.push(literal_f32(m.data(), &[din as i64, din as i64]).unwrap());
    }
    for &(din, _) in &meta.factor_dims {
        args.push(literal_f32(&vec![1.0f32; din], &[din as i64]).unwrap());
    }
    for &(_, dout) in &meta.factor_dims {
        args.push(literal_f32(&vec![1.0f32; dout], &[dout as i64]).unwrap());
    }
    args.push(literal_scalar(0.9).unwrap());
    args.push(literal_scalar(0.0).unwrap()); // flag OFF
    let out = bundle.mkor_step.run(&args).unwrap();
    // Factors unchanged (identity in, identity out).
    for (j, &(_, dout)) in meta.factor_dims.iter().enumerate() {
        let got = out[np + j].to_vec::<f32>().unwrap();
        let want = Matrix::identity(dout);
        for (a, b) in got.iter().zip(want.data()) {
            assert!((a - b).abs() < 1e-6);
        }
    }
    let _ = nm;
}
