//! The observability layer's load-bearing invariant, asserted end to end:
//! **telemetry never perturbs numerics**. A traced sweep must produce
//! deterministic artifacts (CSV and JSON) byte-identical to the untraced
//! run's, while the trace file itself decodes into valid events covering
//! the instrumented phases (step / inverse_update / allreduce / cell_done).
//!
//! One `#[test]` fn owns the whole flow: the sink is process-global, so
//! splitting install → run → finish across tests in this binary would race.

use mkor::experiments::convergence::{RunOpts, TaskKind};
use mkor::obs::{self, EventKind, TraceSummary};
use mkor::sweep::{run_sweep, SweepGrid, SweepOptions};

fn tiny_opts(jobs: usize) -> SweepOptions {
    SweepOptions {
        jobs,
        run: RunOpts {
            steps: 6,
            // Two data-parallel workers per cell so the ring collective
            // actually runs (w=1 short-circuits without touching the wire
            // and emits no allreduce events).
            workers: 2,
            batch: 16,
            eval_every: 3,
            hidden: vec![16],
            ..Default::default()
        },
        verbose: false,
    }
}

#[test]
fn traced_sweep_artifacts_are_byte_identical_and_the_trace_decodes() {
    let task = TaskKind::Images;
    // A 3×3 mkor grid: f=2 guarantees inverse updates inside the 6-step
    // budget, and crossing gamma exercises distinct cells.
    let grid =
        SweepGrid::parse("mkor:f={2,3,5},gamma={0.9,0.95,0.99}", &task, 0).unwrap();
    assert_eq!(grid.len(), 9);
    let opts = tiny_opts(2);

    // Baseline: tracing disabled (no sink installed).
    assert!(!obs::enabled());
    let untraced = run_sweep(&grid, &opts);
    let (base_csv, base_json) =
        (untraced.to_csv_deterministic(), format!("{:#}", untraced.to_json_with(true)));

    // Same sweep with the JSONL sink live.
    let dir = std::env::temp_dir().join(format!("mkor-trace-obs-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let trace_path = dir.join("sweep.trace.jsonl");
    obs::install(&trace_path).unwrap();
    assert!(obs::enabled());
    let traced = run_sweep(&grid, &opts);
    let receipt = obs::finish().unwrap().unwrap();
    assert!(!obs::enabled());
    assert!(receipt.events > 0, "a traced sweep must write events");

    // The invariant: trace-on ≡ trace-off, byte for byte.
    assert_eq!(base_csv, traced.to_csv_deterministic());
    assert_eq!(base_json, format!("{:#}", traced.to_json_with(true)));

    // The trace file re-validates line by line and covers the phases the
    // acceptance walkthrough keys on.
    let log = obs::read_trace(&trace_path).unwrap();
    assert!(!log.torn_tail);
    assert_eq!(log.events.len() as u64, receipt.events);
    let count =
        |k: EventKind| log.events.iter().filter(|e| e.kind == k).count();
    // 9 cells × 6 steps, each step timed.
    assert_eq!(count(EventKind::Step), 9 * 6);
    assert_eq!(count(EventKind::CellDone), 9);
    assert!(count(EventKind::InverseUpdate) > 0, "f<=5 over 6 steps must invert");
    assert!(count(EventKind::Allreduce) > 0, "2 workers per cell must all-reduce");
    // Every timed event carries a sane duration.
    for ev in &log.events {
        if let Some(s) = ev.secs() {
            assert!(s.is_finite() && s >= 0.0, "{ev:?}");
        }
    }

    // The summarize table has the rows the CLI walkthrough greps for.
    let rendered = TraceSummary::from_events(&log.events).render();
    for row in ["| step", "| inverse_update", "| allreduce", "| cell_done"] {
        assert!(rendered.contains(row), "missing {row:?} in:\n{rendered}");
    }

    // The registry saw the same run. Registry updates are gated on the
    // sink like events are, so the untraced baseline contributed nothing
    // and the traced sweep accounts for every tally exactly.
    let reg = obs::registry::global_snapshot();
    assert!(reg.counter("mkor.inverse_updates") > 0);
    assert!(reg.counter("collective.allreduces") > 0);
    assert_eq!(reg.counter("sweep.cells_done"), 9);
    assert_eq!(reg.counter("trainer.steps"), 9 * 6);

    let _ = std::fs::remove_dir_all(&dir);
}
