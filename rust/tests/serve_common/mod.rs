//! Shared harness for the `serve_*` integration tests: spawn the real
//! `mkor serve` daemon on an ephemeral port, parse the advertised
//! address, and build the reference artifacts jobs are compared against.
#![allow(dead_code)] // each test binary uses a different subset

use mkor::serve::JobSpec;
use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, ExitStatus, Stdio};
use std::time::{Duration, Instant};

pub const BIN: &str = env!("CARGO_BIN_EXE_mkor");

/// The acceptance grid shared with `sweep_mp.rs`: 3×3 (f × damping).
pub const SPECS: &str = "kfac:f={5,10,50},damping={0.01,0.03,0.1}";

pub fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mkor-serve-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The job every serve test submits: tiny cells, deterministic-friendly,
/// flag-for-flag identical to [`reference_artifacts`]'s direct CLI run.
pub fn acceptance_job() -> JobSpec {
    let mut spec = JobSpec::new(SPECS, "images");
    spec.steps = 4;
    spec.lr = 0.1;
    spec.cell_workers = 1;
    spec.batch = 16;
    spec.seed = 0;
    spec.eval_every = 2;
    spec.hidden = vec![16];
    spec.job_workers = 1;
    spec
}

/// Reference bytes from `mkor sweep --jobs 1 --deterministic` with the
/// same parameters as [`acceptance_job`]: `(csv, json)`.
pub fn reference_artifacts(dir: &Path) -> (String, String) {
    let csv = dir.join("ref.csv");
    let json = dir.join("ref.json");
    let mut cmd = Command::new(BIN);
    cmd.args([
        "sweep",
        "--specs",
        SPECS,
        "--task",
        "images",
        "--steps",
        "4",
        "--lr",
        "0.1",
        "--cell-workers",
        "1",
        "--batch",
        "16",
        "--seed",
        "0",
        "--eval-every",
        "2",
        "--hidden",
        "16",
        "--jobs",
        "1",
        "--deterministic",
        "--quiet",
    ]);
    cmd.arg("--out").arg(&csv).arg("--json").arg(&json);
    let out = cmd.output().expect("spawning mkor sweep");
    assert!(
        out.status.success(),
        "reference sweep failed:\n{}\n{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (read(&csv), read(&json))
}

pub fn read(path: &Path) -> String {
    std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

/// A live `mkor serve` child process bound to an ephemeral port.
pub struct Daemon {
    pub child: Child,
    pub addr: String,
    pub dir: PathBuf,
}

/// Spawn `mkor serve --addr 127.0.0.1:0 --dir <dir> <extra_args>` with
/// `envs`, wait for the advertised address on stdout, and keep the rest
/// of stdout drained so the daemon can never block on a full pipe.
pub fn spawn_daemon(dir: &Path, extra_args: &[&str], envs: &[(&str, &str)]) -> Daemon {
    let mut cmd = Command::new(BIN);
    cmd.args(["serve", "--addr", "127.0.0.1:0", "--dir"]).arg(dir);
    cmd.args(extra_args);
    for (k, v) in envs {
        cmd.env(k, v);
    }
    cmd.stdout(Stdio::piped()).stderr(Stdio::inherit());
    let mut child = cmd.spawn().expect("spawning mkor serve");
    let stdout = child.stdout.take().unwrap();
    let mut reader = BufReader::new(stdout);
    let mut line = String::new();
    let addr = loop {
        line.clear();
        let n = reader.read_line(&mut line).expect("reading daemon stdout");
        assert!(n > 0, "daemon exited before advertising its address");
        if let Some(rest) = line.trim().strip_prefix("mkor serve: listening on ") {
            break rest.to_string();
        }
    };
    std::thread::spawn(move || {
        let mut sink = String::new();
        while reader.read_line(&mut sink).map(|n| n > 0).unwrap_or(false) {
            sink.clear();
        }
    });
    Daemon { child, addr, dir: dir.to_path_buf() }
}

impl Daemon {
    /// Wait for the daemon to exit on its own (after `shutdown` or an
    /// injected crash); panics past `timeout`.
    pub fn wait_exit(&mut self, timeout: Duration) -> ExitStatus {
        let t0 = Instant::now();
        loop {
            if let Some(status) = self.child.try_wait().expect("polling daemon") {
                return status;
            }
            assert!(t0.elapsed() < timeout, "daemon did not exit within {timeout:?}");
            std::thread::sleep(Duration::from_millis(50));
        }
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Every journal line must parse and carry the journal schema version —
/// the crash-safety contract tests assert after abuse.
pub fn assert_journal_valid(dir: &Path) {
    let path = dir.join("journal.jsonl");
    let text = read(&path);
    for (i, line) in text.lines().enumerate() {
        let v = mkor::util::json::Json::parse(line)
            .unwrap_or_else(|e| panic!("journal line {}: {e}\n{line}", i + 1));
        assert_eq!(
            v.require_usize("v").unwrap() as u64,
            mkor::serve::queue::JOURNAL_FORMAT_VERSION,
            "journal line {}",
            i + 1
        );
    }
}
