//! End-to-end sweep-engine tests through the public API: grid → executor
//! → merged report, including the acceptance contract that a `--jobs N`
//! sweep produces the same cell set and per-cell results as `--jobs 1`
//! (deterministic ordering, per-cell seeding independent of scheduling).

use mkor::experiments::convergence::{RunOpts, TaskKind};
use mkor::sweep::{run_sweep, run_sweep_resumed, CellStatus, SweepGrid, SweepOptions, SweepReport};
use mkor::util::json::Json;

fn tiny_opts(jobs: usize) -> SweepOptions {
    SweepOptions {
        jobs,
        run: RunOpts {
            steps: 6,
            workers: 1,
            batch: 16,
            eval_every: 3,
            hidden: vec![16],
            target_metric: Some(0.4),
            ..Default::default()
        },
        verbose: false,
    }
}

#[test]
fn braced_3x3_grid_is_byte_identical_for_any_job_count() {
    // A 3×3 braced grid (f × damping), as in the acceptance criterion.
    let task = TaskKind::Images;
    let grid = SweepGrid::parse("kfac:f={5,10,50},damping={0.01,0.03,0.1}", &task, 0).unwrap();
    assert_eq!(grid.len(), 9);
    let serial = run_sweep(&grid, &tiny_opts(1));
    let fanned = run_sweep(&grid, &tiny_opts(4));
    // Cell set and per-cell results are byte-identical to the serial run;
    // only measured wall-clock columns may differ.
    assert_eq!(serial.to_csv_deterministic(), fanned.to_csv_deterministic());
    let (sj, fj) = (serial.to_json_with(true), fanned.to_json_with(true));
    assert_eq!(format!("{sj:#}"), format!("{fj:#}"));
    // One data row per cell, keyed by the canonical spec string.
    let csv = fanned.to_csv_deterministic();
    assert_eq!(csv.trim().lines().count(), 1 + 9, "{csv}");
    assert!(csv.contains("\"kfac:f=5,damping=0.01\""), "{csv}");
}

#[test]
fn charlm_transformer_sweep_is_byte_identical_for_any_job_count() {
    // The causal-transformer task folds sequence positions into the batch
    // dimension, so its shard math is the k-scaled path in the trainer —
    // the `--jobs 1` vs `--jobs N` contract must hold there too.
    let task = TaskKind::CharLm { vocab: 48, seq_len: 16 };
    let grid =
        SweepGrid::parse("mkor:f=2;mkor-h:min_steps=2,switch_beta=0.8", &task, 3).unwrap();
    assert_eq!(grid.len(), 2);
    let mut opts = tiny_opts(1);
    opts.run.steps = 4;
    opts.run.batch = 8;
    opts.run.hidden = Vec::new(); // charlm ignores hidden widths
    let serial = run_sweep(&grid, &opts);
    opts.jobs = 3;
    let fanned = run_sweep(&grid, &opts);
    assert_eq!(serial.to_csv_deterministic(), fanned.to_csv_deterministic());
    for c in &fanned.cells {
        assert_eq!(c.status, CellStatus::Ok, "{}", c.spec);
        assert!(c.final_loss().is_finite(), "{}", c.spec);
    }
}

#[test]
fn seed_axis_and_templates_expand_into_independent_cells() {
    let task = TaskKind::Images;
    let grid = SweepGrid::parse("mkor:f={1,5};sgd x seed=0..2", &task, 7).unwrap();
    assert_eq!(grid.len(), 4);
    let report = run_sweep(&grid, &tiny_opts(2));
    // Grid order survives the fan-out.
    let specs: Vec<&str> = report.cells.iter().map(|c| c.spec.as_str()).collect();
    assert_eq!(specs, vec!["mkor:f=1", "mkor:f=5", "sgd", "sgd"]);
    let seeds: Vec<u64> = report.cells.iter().map(|c| c.seed).collect();
    assert_eq!(seeds, vec![7, 7, 0, 1]);
    // Every cell ran its budget and is individually addressable.
    for c in &report.cells {
        assert_eq!(c.status, CellStatus::Ok, "{}", c.spec);
        assert_eq!(c.steps_run(), 6);
    }
    assert!(report.find("sgd", 1).is_some());
    // Same spec, different seed → different trajectory (cells are
    // genuinely independent runs, not copies).
    let (a, b) = (report.find("sgd", 0).unwrap(), report.find("sgd", 1).unwrap());
    assert_ne!(a.final_loss(), b.final_loss());
}

#[test]
fn a_diverged_cell_fails_alone_and_the_sweep_survives() {
    // An absurd lr diverges SGD; the braced sibling cells stay healthy.
    // (A larger step budget than the other tests: overflow to non-finite
    // weights takes a few steps of compounding.)
    let task = TaskKind::Images;
    let grid = SweepGrid::parse("sgd:lr={1e6,0.1}", &task, 1).unwrap();
    let mut opts = tiny_opts(2);
    opts.run.steps = 100;
    let report = run_sweep(&grid, &opts);
    let (ok, diverged, panicked) = report.counts();
    assert_eq!((ok, diverged, panicked), (1, 1, 0), "{:?}", report.counts());
    assert_eq!(report.cells[0].status, CellStatus::Diverged);
    assert_eq!(report.cells[1].status, CellStatus::Ok);
    // The diverged cell still reports a row with its partial record.
    let csv = report.to_csv();
    assert_eq!(csv.trim().lines().count(), 3);
    assert!(csv.contains("diverged"), "{csv}");
}

#[test]
fn interrupted_sweep_resumes_from_its_csv_and_reruns_only_missing_cells() {
    // The full `--resume` flow: run a grid, save the CSV, drop rows (the
    // "interrupted" state), reload via load_csv, and resume — only the
    // missing cells re-run, reused rows merge unchanged, and the final
    // artifact is byte-identical to the uninterrupted sweep's.
    let dir = std::env::temp_dir().join(format!("mkor-sweep-resume-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let csv_path = dir.join("sweep.csv");

    let task = TaskKind::Images;
    let grid = SweepGrid::parse("mkor:f={1,5};sgd:lr={0.1,0.05}", &task, 2).unwrap();
    assert_eq!(grid.len(), 4);
    let opts = tiny_opts(2);
    let full = run_sweep(&grid, &opts);
    full.save_csv(&csv_path).unwrap();
    let full_csv = std::fs::read_to_string(&csv_path).unwrap();

    // Interrupt: keep only the header + first two rows.
    let kept: Vec<&str> = full_csv.trim_end().lines().take(3).collect();
    std::fs::write(&csv_path, format!("{}\n", kept.join("\n"))).unwrap();

    let prior = SweepReport::load_csv(&csv_path).unwrap();
    assert_eq!(prior.cells.len(), 2);
    let resumed = run_sweep_resumed(&grid, &opts, Some(&prior));
    let skipped: Vec<bool> = resumed.cells.iter().map(|c| c.skipped).collect();
    assert_eq!(skipped, vec![true, true, false, false]);
    for c in &resumed.cells {
        assert_eq!(c.status, CellStatus::Ok, "{}", c.spec);
    }
    // Cells that differ only in lr were keyed apart correctly (lr-axis
    // cells share a spec string, so lr is part of the resume key).
    assert_eq!(resumed.cells[2].spec, "sgd");
    assert_eq!(resumed.cells[3].spec, "sgd");
    assert_ne!(resumed.cells[2].lr, resumed.cells[3].lr);
    // The merged deterministic artifact matches the uninterrupted run's.
    assert_eq!(resumed.to_csv_deterministic(), full.to_csv_deterministic());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sweep_json_artifact_reparses_with_per_cell_results() {
    let task = TaskKind::Images;
    let grid = SweepGrid::parse("mkor:f={1,5} x seed=0..2", &task, 0).unwrap();
    let report = run_sweep(&grid, &tiny_opts(3));
    let text = format!("{:#}", report.to_json());
    let j = Json::parse(&text).unwrap();
    assert_eq!(j.get("n_cells").unwrap().as_usize(), Some(4));
    let cells = j.get("cells").unwrap().as_arr().unwrap();
    assert_eq!(cells.len(), 4);
    for c in cells {
        assert_eq!(c.require_str("status").unwrap(), "ok");
        assert_eq!(c.get("loss").unwrap().as_arr().unwrap().len(), 6);
        assert!(c.get("final_loss").unwrap().as_f64().unwrap().is_finite());
    }
}
