//! Checkpoint subsystem acceptance tests, through the public API:
//!
//! 1. every optimizer spec in the registry round-trips its state
//!    (save → binary codec → load → `state_dict()` equality);
//! 2. bitwise resume equivalence on the MLP task — 2N straight steps vs.
//!    N + checkpoint + restore-into-fresh-trainer + N produce identical
//!    loss series and final weights for `mkor`, `mkor-h`, `kfac`, `lamb`;
//! 3. every error path fails loudly: wrong spec, wrong shape, truncated
//!    `.bin`, missing manifest key.

use mkor::checkpoint::{Checkpoint, CheckpointError, Checkpointable, StateDict, StateError};
use mkor::coordinator::{Target, TrainerBuilder};
use mkor::data::classification::{Dataset, TaskConfig};
use mkor::experiments::convergence::{run_record, RunOpts, TaskKind};
use mkor::linalg::{ops, Matrix};
use mkor::model::{Activation, Capture, Dense, LayerShape, Mlp, Model};
use mkor::optim::{Optimizer, OptimizerSpec, ALL_OPTIMIZERS};
use mkor::util::timer::PhaseTimer;
use mkor::util::Rng;
use std::path::PathBuf;

fn temp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mkor-it-ckpt-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn toy_capture(shape: LayerShape, b: usize, rng: &mut Rng) -> Capture {
    let a = Matrix::randn(shape.d_in, b, 1.0, rng);
    let g = Matrix::randn(shape.d_out, b, 1.0, rng);
    let mut dw = ops::matmul_nt(&g, &a);
    dw.scale(1.0 / b as f32);
    let db = vec![0.0; shape.d_out];
    Capture { a, g, dw, db }
}

#[test]
fn every_registry_spec_roundtrips_its_state() {
    // Bare names cover the registry; keyed variants cover every MKOR
    // backend (the backend moments are part of the state) and the
    // non-default refresh cadences.
    let specs = [
        "sgd",
        "adam",
        "lamb",
        "kfac",
        "sngd",
        "eva",
        "mkor",
        "mkor-h",
        "mkor:backend=adam",
        "mkor:backend=lamb",
        "mkor-h:backend=adam",
        "mkor:half=none",
        "kfac:f=2",
        "sngd:f=2",
        "eva:f=2,beta=0.5",
    ];
    for name in ALL_OPTIMIZERS {
        assert!(specs.contains(name), "registry spec `{name}` missing from the round-trip set");
    }
    let shapes = [LayerShape::new(6, 4), LayerShape::new(4, 3)];
    for s in specs {
        let spec = OptimizerSpec::parse(s).unwrap_or_else(|e| panic!("{s}: {e}"));
        let mut opt = spec.build(&shapes);
        // Populate real state: several steps (crossing factor refreshes)
        // plus observed losses (MKOR-H's switching state).
        let mut rng = Rng::new(1);
        let mut layers: Vec<Dense> = shapes
            .iter()
            .map(|&sh| Dense::init(sh, Activation::Linear, &mut rng))
            .collect();
        let mut timer = PhaseTimer::new();
        for step in 0..5 {
            let caps: Vec<Capture> =
                shapes.iter().map(|&sh| toy_capture(sh, 6, &mut rng)).collect();
            opt.step(&mut layers, &caps, 0.05, &mut timer);
            opt.observe_loss(2.0 - 0.1 * step as f64);
        }
        let sd = opt.state_dict();
        // Through the versioned binary codec and back, bit-for-bit.
        let decoded = StateDict::from_bytes(&sd.to_bytes())
            .unwrap_or_else(|e| panic!("{s}: decode: {e}"));
        assert_eq!(decoded, sd, "{s}: codec round-trip");
        // Into a freshly-built optimizer of the same spec.
        let mut fresh = spec.build(&shapes);
        fresh
            .load_state_dict(&decoded)
            .unwrap_or_else(|e| panic!("{s}: load: {e}"));
        assert_eq!(fresh.state_dict(), sd, "{s}: state_dict equality after load");
        assert_eq!(fresh.steps_done(), opt.steps_done(), "{s}");
    }
}

/// Build the MLP-task trainer the equivalence tests share.
fn make_trainer(spec: &str, seed: u64) -> (mkor::coordinator::Trainer, Dataset) {
    let mut cfg = TaskConfig::new("t", 16, 3);
    cfg.train = 256;
    cfg.test = 64;
    cfg.seed = seed;
    let ds = Dataset::generate(cfg);
    let mut rng = Rng::new(seed);
    let model = Mlp::new(&[16, 24, 3], Activation::Relu, &mut rng);
    let trainer = TrainerBuilder::new(model)
        .optimizer_str(spec)
        .unwrap()
        .constant_lr(0.05)
        .workers(2)
        .build();
    (trainer, ds)
}

#[test]
fn bitwise_resume_equivalence_for_key_specs() {
    // The headline acceptance property, for the four specs the issue
    // names: 2N straight steps vs. N + checkpoint + restore into a fresh
    // trainer ("fresh process": everything rebuilt from spec + checkpoint)
    // + N more — identical loss series AND identical final weights.
    for (i, spec) in ["mkor", "mkor-h:min_steps=2", "kfac:f=3", "lamb"].into_iter().enumerate() {
        let dir = temp_dir(&format!("equiv-{i}"));
        let (mut straight, ds) = make_trainer(spec, 40 + i as u64);
        let batches = ds.epoch_batches(64, 0);
        let n = batches.len() / 2;

        let mut straight_losses = Vec::new();
        for b in &batches {
            let loss = straight.step(&b.x, &Target::Labels(b.labels.clone())).unwrap();
            straight_losses.push(loss);
        }

        let (mut head, _) = make_trainer(spec, 40 + i as u64);
        for b in &batches[..n] {
            head.step(&b.x, &Target::Labels(b.labels.clone())).unwrap();
        }
        head.save_checkpoint(&dir).unwrap();
        drop(head); // the "killed process"

        let mut rng = Rng::new(40 + i as u64);
        let model = Mlp::new(&[16, 24, 3], Activation::Relu, &mut rng);
        let mut resumed = TrainerBuilder::new(model)
            .optimizer_str(spec)
            .unwrap()
            .constant_lr(0.05)
            .workers(2)
            .resume_from(&dir)
            .try_build()
            .unwrap_or_else(|e| panic!("{spec}: resume: {e}"));
        assert_eq!(resumed.steps_done(), n, "{spec}");
        for b in &batches[n..] {
            resumed.step(&b.x, &Target::Labels(b.labels.clone())).unwrap();
        }

        let resumed_losses: Vec<f64> =
            resumed.record.steps.iter().map(|s| s.loss).collect();
        assert_eq!(straight_losses.len(), resumed_losses.len(), "{spec}");
        for (step, (a, b)) in straight_losses.iter().zip(&resumed_losses).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{spec}: loss differs at step {step}");
        }
        for (a, b) in straight.leader().layers().iter().zip(resumed.leader().layers()) {
            assert_eq!(a.w.data(), b.w.data(), "{spec}: final weights differ");
            assert_eq!(a.bias, b.bias, "{spec}: final biases differ");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn run_record_resume_matches_on_every_proxy_task_field() {
    // The convergence-harness path (what `mkor sweep` cells run through):
    // RunOpts checkpoint knobs + deterministic data-stream replay.
    let dir = temp_dir("run-record");
    let spec = OptimizerSpec::parse("mkor-h:min_steps=2").unwrap();
    let base = RunOpts {
        steps: 14,
        hidden: vec![24],
        eval_every: 7,
        workers: 1,
        ..Default::default()
    };
    let straight = run_record(&TaskKind::Autoencoder, &spec, "r", &base);

    let mut head = base.clone();
    head.steps = 7;
    head.checkpoint_every = 7;
    head.checkpoint_dir = Some(dir.clone());
    run_record(&TaskKind::Autoencoder, &spec, "r", &head);

    let mut tail = base.clone();
    tail.checkpoint_dir = Some(dir.clone());
    tail.resume = true;
    let resumed = run_record(&TaskKind::Autoencoder, &spec, "r", &tail);

    assert_eq!(straight.steps.len(), resumed.steps.len());
    for (a, b) in straight.steps.iter().zip(&resumed.steps) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        assert_eq!(a.eval_metric, b.eval_metric);
        assert_eq!(a.sync_comm_bytes, b.sync_comm_bytes);
    }
    assert_eq!(straight.switched_at, resumed.switched_at);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn error_paths_fail_loudly() {
    let dir = temp_dir("errors");
    let (mut tr, ds) = make_trainer("mkor", 50);
    let b = &ds.epoch_batches(64, 0)[0];
    tr.step(&b.x, &Target::Labels(b.labels.clone())).unwrap();
    tr.save_checkpoint(&dir).unwrap();

    // Wrong spec: the checkpoint's canonical spec is validated first.
    let mut rng = Rng::new(50);
    let model = Mlp::new(&[16, 24, 3], Activation::Relu, &mut rng);
    let e = TrainerBuilder::new(model)
        .optimizer_str("eva")
        .unwrap()
        .resume_from(&dir)
        .try_build()
        .unwrap_err();
    assert!(matches!(e, CheckpointError::SpecMismatch { .. }), "{e:?}");

    // Wrong shape: state loads are validated tensor-by-tensor.
    let model = Mlp::new(&[16, 32, 3], Activation::Relu, &mut rng);
    let e = TrainerBuilder::new(model)
        .optimizer_str("mkor")
        .unwrap()
        .resume_from(&dir)
        .try_build()
        .unwrap_err();
    match e {
        CheckpointError::State { source, .. } => {
            assert!(matches!(source, StateError::ShapeMismatch { .. }), "{source:?}");
        }
        other => panic!("expected State(ShapeMismatch), got {other:?}"),
    }

    // Truncated .bin: the manifest hash catches it before decoding. (Blob
    // filenames are step-stamped, so resolve through the manifest.)
    let manifest_json =
        mkor::util::json::Json::from_file(&dir.join("manifest.json")).unwrap();
    let bin = dir.join(
        manifest_json
            .get("components")
            .unwrap()
            .get("optimizer")
            .unwrap()
            .require_str("file")
            .unwrap(),
    );
    let bytes = std::fs::read(&bin).unwrap();
    std::fs::write(&bin, &bytes[..bytes.len() / 2]).unwrap();
    let e = Checkpoint::load(&dir).unwrap_err();
    assert!(matches!(e, CheckpointError::HashMismatch { .. }), "{e:?}");
    // And the raw codec reports truncation on its own.
    let e = StateDict::from_bytes(&bytes[..bytes.len() / 2]).unwrap_err();
    assert!(matches!(e, StateError::Truncated { .. }), "{e:?}");
    std::fs::write(&bin, &bytes).unwrap();

    // Missing manifest key.
    let manifest = dir.join("manifest.json");
    let text = std::fs::read_to_string(&manifest).unwrap();
    std::fs::write(&manifest, text.replace("\"spec\"", "\"spe\"")).unwrap();
    let e = Checkpoint::load(&dir).unwrap_err();
    assert!(
        matches!(&e, CheckpointError::MissingManifestKey { key } if key == "spec"),
        "{e:?}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn rng_is_checkpointable_as_a_component() {
    // The harness RNG implements Checkpointable and rides along as an
    // extra checkpoint component.
    let dir = temp_dir("rng");
    let mut rng = Rng::new(7);
    let _ = rng.gaussian();
    let mut ckpt = Checkpoint {
        step: 0,
        spec: "sgd".to_string(),
        optimizer: "sgd".to_string(),
        task: String::new(),
        run_name: "rng-test".to_string(),
        components: Default::default(),
        record: None,
    };
    ckpt.components.insert("rng".to_string(), rng.state_dict());
    ckpt.save(&dir).unwrap();
    let loaded = Checkpoint::load(&dir).unwrap();
    let mut restored = Rng::new(0);
    restored.load_state_dict(loaded.component("rng").unwrap()).unwrap();
    for _ in 0..16 {
        assert_eq!(rng.next_u64(), restored.next_u64());
    }
    let _ = std::fs::remove_dir_all(&dir);
}
