//! Parallel-vs-serial bitwise-equality properties for every dispatched
//! linalg kernel, across thread counts and ragged shapes — the determinism
//! invariant the engine promises (`rust/src/linalg/engine/`): results are
//! **bitwise identical at any `--threads`**, because row ownership is
//! exclusive, per-element accumulation order is fixed by the problem shape
//! and the constant tile sizes, and the engine/serial dispatch depends on
//! problem size only.
//!
//! Also covers the perf-report schema round trip (the contract CI's
//! perf-smoke job validates against).

use mkor::linalg::{engine, ops, Matrix};
use mkor::perf::{PerfReport, TimerConfig};
use mkor::util::json::Json;
use mkor::util::Rng;

/// Thread counts the properties sweep (1 = serial baseline; 7 is
/// deliberately ragged against every shape below).
const THREADS: &[usize] = &[1, 2, 7];

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i}: {x} vs {y}");
    }
}

/// Shapes straddling the GEMM dispatch threshold, ragged on purpose.
/// (161·133·129 ≈ 2.8M ≥ 2²¹ forces the engine path; the small ones stay
/// on the serial path at every thread count.)
fn gemm_shapes() -> Vec<(usize, usize, usize)> {
    vec![(13, 7, 11), (70, 129, 33), (161, 133, 129), (160, 160, 160)]
}

#[test]
fn matmul_bitwise_identical_across_thread_counts() {
    let mut rng = Rng::new(100);
    for (m, k, n) in gemm_shapes() {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        engine::set_threads(1);
        let base = ops::matmul(&a, &b);
        for &t in THREADS {
            engine::set_threads(t);
            let c = ops::matmul(&a, &b);
            assert_bits_eq(base.data(), c.data(), &format!("matmul {m}x{k}x{n} t={t}"));
        }
    }
}

#[test]
fn matmul_nt_bitwise_identical_across_thread_counts() {
    let mut rng = Rng::new(101);
    for (m, k, n) in gemm_shapes() {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(n, k, 1.0, &mut rng); // B is n×k, C = A·Bᵀ
        engine::set_threads(1);
        let base = ops::matmul_nt(&a, &b);
        for &t in THREADS {
            engine::set_threads(t);
            let c = ops::matmul_nt(&a, &b);
            assert_bits_eq(base.data(), c.data(), &format!("matmul_nt {m}x{k}x{n} t={t}"));
        }
    }
}

#[test]
fn matmul_tn_bitwise_identical_across_thread_counts() {
    let mut rng = Rng::new(102);
    for (m, k, n) in gemm_shapes() {
        let a = Matrix::randn(k, m, 1.0, &mut rng); // A is k×m, C = Aᵀ·B
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        engine::set_threads(1);
        let base = ops::matmul_tn(&a, &b);
        for &t in THREADS {
            engine::set_threads(t);
            let c = ops::matmul_tn(&a, &b);
            assert_bits_eq(base.data(), c.data(), &format!("matmul_tn {m}x{k}x{n} t={t}"));
        }
    }
}

#[test]
fn matvec_variants_bitwise_identical_across_thread_counts() {
    let mut rng = Rng::new(103);
    // 520×521 ≥ 2¹⁸ elements forces the engine path; 37×19 stays serial.
    for (rows, cols) in [(37usize, 19usize), (520, 521)] {
        let a = Matrix::randn(rows, cols, 1.0, &mut rng);
        let x: Vec<f32> = (0..cols).map(|_| rng.gaussian_f32()).collect();
        let xr: Vec<f32> = (0..rows).map(|_| rng.gaussian_f32()).collect();
        engine::set_threads(1);
        let base = ops::matvec(&a, &x);
        let base_t = ops::matvec_t(&a, &xr);
        for &t in THREADS {
            engine::set_threads(t);
            assert_bits_eq(&base, &ops::matvec(&a, &x), &format!("matvec {rows}x{cols} t={t}"));
            assert_bits_eq(
                &base_t,
                &ops::matvec_t(&a, &xr),
                &format!("matvec_t {rows}x{cols} t={t}"),
            );
        }
    }
}

#[test]
fn rank1_update_bitwise_identical_across_thread_counts() {
    let mut rng = Rng::new(104);
    for n in [23usize, 520] {
        let init = Matrix::rand_spd(n, 0.1, &mut rng);
        let u: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        engine::set_threads(1);
        let mut base = init.clone();
        ops::scaled_rank1_update(&mut base, 0.95, 0.05, &u);
        for &t in THREADS {
            engine::set_threads(t);
            let mut m = init.clone();
            ops::scaled_rank1_update(&mut m, 0.95, 0.05, &u);
            assert_bits_eq(base.data(), m.data(), &format!("rank1 n={n} t={t}"));
        }
    }
}

#[test]
fn col_mean_bitwise_identical_across_thread_counts() {
    let mut rng = Rng::new(105);
    // d×b capture shapes: small serial case and an engine-path case
    // (600×512 ≥ 2¹⁸), plus a ragged b.
    for (d, b) in [(33usize, 17usize), (600, 512), (601, 437)] {
        let a = Matrix::randn(d, b, 1.0, &mut rng);
        engine::set_threads(1);
        let base = ops::col_mean(&a);
        for &t in THREADS {
            engine::set_threads(t);
            assert_bits_eq(&base, &ops::col_mean(&a), &format!("col_mean {d}x{b} t={t}"));
        }
    }
}

/// The fused Sherman–Morrison sequence MKOR runs per layer (Algorithm 1):
/// col-mean of the d×b capture → matvec through the inverse → dot →
/// fused rank-1 update. Chained across several iterations it must stay
/// bitwise identical whatever the thread count — this is exactly the
/// property the checkpoint-resume byte-equality suite leans on.
#[test]
fn sm_update_sequence_bitwise_identical_across_thread_counts() {
    fn run(threads: usize) -> Matrix {
        engine::set_threads(threads);
        let mut rng = Rng::new(106);
        let d = 520; // d² above the slice threshold: engine path engaged
        let mut inv = Matrix::rand_spd(d, 0.1, &mut rng);
        for step in 0..3 {
            let capture = Matrix::randn(d, 64, 1.0, &mut rng);
            let v = ops::col_mean(&capture);
            let mut u = vec![0.0f32; d];
            ops::matvec_into(&inv, &v, &mut u);
            let denom = 1.0 + ops::dot(&v, &u) as f32;
            let gamma = 0.9 + 0.01 * step as f32;
            ops::scaled_rank1_update(&mut inv, 1.0 / gamma, -1.0 / (gamma * denom), &u);
        }
        inv
    }
    let base = run(1);
    for &t in &[2usize, 7] {
        let got = run(t);
        assert_bits_eq(base.data(), got.data(), &format!("sm sequence t={t}"));
    }
}

/// The dispatch wiring itself: the test shapes above genuinely straddle
/// the thresholds (guards against silently shifting a constant so the
/// "engine path" cases quietly all go serial).
#[test]
fn dispatch_thresholds_are_straddled_by_test_shapes() {
    assert!(13 * 7 * 11 < engine::GEMM_PAR_MIN_WORK);
    assert!(161 * 133 * 129 >= engine::GEMM_PAR_MIN_WORK);
    assert!(160 * 160 * 160 >= engine::GEMM_PAR_MIN_WORK);
    assert!(37 * 19 < engine::SLICE_PAR_MIN_ELEMS);
    assert!(520 * 521 >= engine::SLICE_PAR_MIN_ELEMS);
    assert!(600 * 512 >= engine::SLICE_PAR_MIN_ELEMS);
    assert!(520 * 520 >= engine::SLICE_PAR_MIN_ELEMS);
}

/// Perf-report schema contract: emit → parse → same content, and the
/// emitted text is valid JSON with the versioned keys CI checks for.
#[test]
fn perf_report_schema_round_trips_through_text() {
    let report = PerfReport {
        schema_version: mkor::perf::SCHEMA_VERSION,
        quick: true,
        threads: 2,
        hw_threads: 8,
        os: "linux".into(),
        arch: "x86_64".into(),
        warmup: TimerConfig::quick().warmup,
        repeats: TimerConfig::quick().repeats,
        gemm: vec![mkor::perf::suite::GemmPoint {
            kind: "nt".into(),
            d: 128,
            serial_gflops: 4.5,
            engine_gflops: 9.0,
            speedup: 2.0,
        }],
        optimizers: vec![mkor::perf::suite::OptPoint {
            name: "mkor-h".into(),
            steps_per_sec: 1250.25,
        }],
        allreduce: vec![mkor::perf::suite::RingPoint {
            workers: 4,
            elems: 16384,
            fp32_gbps: 4.5,
            bf16_gbps: 2.25,
        }],
    };
    report.validate().expect("sample report valid");
    let text = format!("{:#}", report.to_json());
    let parsed = Json::parse(&text).expect("emitted report is valid JSON");
    assert_eq!(parsed.require_usize("schema_version").unwrap(), 1);
    assert!(parsed.get("host").unwrap().require_usize("threads").unwrap() == 2);
    let back = PerfReport::from_json(&parsed).expect("round trip");
    assert_eq!(back.gemm[0].kind, "nt");
    assert_eq!(back.gemm[0].engine_gflops, 9.0);
    assert_eq!(back.optimizers[0].steps_per_sec, 1250.25);
    assert_eq!(back.allreduce[0].bf16_gbps, 2.25);
    back.validate().expect("parsed report valid");
}
