//! Adversarial protocol suite against a live `mkor serve` daemon: every
//! malformed, truncated, oversized, version-skewed or interleaved input
//! must map to a typed error on that line — and the daemon must keep
//! serving, never leak a job into the queue, and never corrupt its
//! journal.

mod serve_common;

use mkor::serve::JobSpec;
use mkor::serve::{Client, MAX_LINE_BYTES};
use mkor::util::json::Json;
use serve_common::{assert_journal_valid, spawn_daemon, tmp};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

/// A fast, valid job for health checks (sub-second to run).
fn tiny_job() -> JobSpec {
    let mut spec = JobSpec::new("lamb", "glue");
    spec.steps = 2;
    spec.cell_workers = 1;
    spec.batch = 16;
    spec.eval_every = 0;
    spec
}

fn error_code(resp: &Json) -> &str {
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false), "expected an error: {resp}");
    resp.get("error").and_then(|e| e.get("code")).and_then(Json::as_str).unwrap()
}

#[test]
fn malformed_corpus_gets_typed_errors_and_daemon_survives() {
    let dir = tmp("corpus");
    let mut daemon = spawn_daemon(&dir, &[], &[]);
    let mut client = Client::connect_retry(&daemon.addr, Duration::from_secs(5)).unwrap();

    let corpus: Vec<(Vec<u8>, &str)> = vec![
        (b"not json at all".to_vec(), "malformed"),
        (b"[1,2,3]".to_vec(), "malformed"),
        (b"{\"v\":1,\"op\":\"ping\"".to_vec(), "malformed"), // truncated JSON
        (b"{}".to_vec(), "version_skew"),
        (b"{\"op\":\"ping\"}".to_vec(), "version_skew"),
        (b"{\"v\":99,\"op\":\"ping\"}".to_vec(), "version_skew"),
        (b"{\"v\":1}".to_vec(), "malformed"),
        (b"{\"v\":1,\"op\":42}".to_vec(), "malformed"),
        (b"{\"v\":1,\"op\":\"frobnicate\"}".to_vec(), "unknown_op"),
        (b"{\"v\":1,\"op\":\"status\"}".to_vec(), "bad_request"),
        (b"{\"v\":1,\"op\":\"cancel\",\"job\":17}".to_vec(), "bad_request"),
        (b"{\"v\":1,\"op\":\"status\",\"job\":\"j999\"}".to_vec(), "unknown_job"),
        (b"{\"v\":1,\"op\":\"result\",\"job\":\"nope\"}".to_vec(), "unknown_job"),
        (b"{\"v\":1,\"op\":\"subscribe\",\"job\":\"j999\"}".to_vec(), "unknown_job"),
        (b"{\"v\":1,\"op\":\"submit\"}".to_vec(), "bad_request"),
        (b"{\"v\":1,\"op\":\"submit\",\"spec\":{\"task\":\"glue\"}}".to_vec(), "bad_request"),
        // Well-typed spec that cannot plan: unknown task / broken grid.
        (
            b"{\"v\":1,\"op\":\"submit\",\"spec\":{\"specs\":\"lamb\",\"task\":\"nope\"}}".to_vec(),
            "bad_request",
        ),
        (
            b"{\"v\":1,\"op\":\"submit\",\"spec\":{\"specs\":\"kfac:f={\",\"task\":\"glue\"}}"
                .to_vec(),
            "bad_request",
        ),
        (
            b"{\"v\":1,\"op\":\"submit\",\"spec\":{\"specs\":\"lamb\",\"task\":\"glue\",\"steps\":0}}"
                .to_vec(),
            "bad_request",
        ),
        (vec![0xff, 0xfe, b'{', b'}'], "malformed"), // invalid UTF-8
        ("x".repeat(MAX_LINE_BYTES + 100).into_bytes(), "oversized"),
    ];
    for (line, want) in &corpus {
        let resp = client.raw_roundtrip(line).unwrap_or_else(|e| {
            panic!("daemon died on {:?}...: {e:#}", String::from_utf8_lossy(&line[..line.len().min(60)]))
        });
        assert_eq!(&error_code(&resp), want, "for line {:?}", String::from_utf8_lossy(&line[..line.len().min(80)]));
        let msg = resp.get("error").unwrap().require_str("message").unwrap();
        assert!(!msg.is_empty(), "errors must carry an actionable message");
    }

    // No bad submit leaked into the queue...
    assert_eq!(client.jobs().unwrap().len(), 0, "corpus must not enqueue anything");
    // ...and the same connection still serves real work end to end.
    assert!(client.ping().unwrap().starts_with("mkor "));
    let job = client.submit(&tiny_job()).unwrap();
    assert_eq!(job, "j1");
    let done = client.wait(&job, Duration::from_secs(60)).unwrap();
    assert_eq!(done.state, "done", "detail: {:?}", done.detail);
    let (csv, json) = client.result(&job).unwrap();
    assert!(csv.starts_with("cell,"), "csv header missing: {csv}");
    assert!(json.contains("\"n_cells\""), "{json}");

    client.shutdown().unwrap();
    let status = daemon.wait_exit(Duration::from_secs(30));
    assert_eq!(status.code(), Some(0), "shutdown must exit cleanly");
    assert_journal_valid(&dir);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn pipelined_and_interleaved_requests_answer_in_order() {
    let dir = tmp("pipeline");
    let mut daemon = spawn_daemon(&dir, &[], &[]);

    // Raw socket: one write carrying good, blank, bad and good lines.
    let mut stream = TcpStream::connect(&daemon.addr).unwrap();
    stream
        .write_all(
            b"{\"v\":1,\"op\":\"ping\"}\n\
              \n\
              {\"v\":1,\"op\":\"frobnicate\"}\n\
              {\"v\":1,\"op\":\"jobs\"}\n",
        )
        .unwrap();
    stream.shutdown(std::net::Shutdown::Write).unwrap();
    let mut text = String::new();
    stream.read_to_string(&mut text).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 3, "blank lines are skipped, all else answered:\n{text}");
    let parsed: Vec<Json> = lines.iter().map(|l| Json::parse(l).unwrap()).collect();
    assert_eq!(parsed[0].get("op").and_then(Json::as_str), Some("ping"));
    assert_eq!(error_code(&parsed[1]), "unknown_op");
    assert_eq!(parsed[2].get("op").and_then(Json::as_str), Some("jobs"));

    // A second client interleaved with the first sees its own ordering.
    let mut a = Client::connect_retry(&daemon.addr, Duration::from_secs(5)).unwrap();
    let mut b = Client::connect_retry(&daemon.addr, Duration::from_secs(5)).unwrap();
    assert!(a.ping().is_ok());
    assert!(b.ping().is_ok());

    b.shutdown().unwrap();
    assert_eq!(daemon.wait_exit(Duration::from_secs(30)).code(), Some(0));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn queue_discipline_capacity_cancel_and_restart() {
    let dir = tmp("queue");
    // capacity 1 *queued* job; each claimed job is held in `running` for
    // 3 s (test hook), giving a deterministic window to observe the
    // full/cancel/not_done behaviors.
    let mut daemon =
        spawn_daemon(&dir, &["--capacity", "1"], &[("MKOR_SERVE_RUN_DELAY_MS", "3000")]);
    let mut client = Client::connect_retry(&daemon.addr, Duration::from_secs(5)).unwrap();

    let j1 = client.submit(&tiny_job()).unwrap();
    // Wait until the runner claims it: the queued slot is free again.
    let t0 = std::time::Instant::now();
    while client.status(&j1).unwrap().state != "running" {
        assert!(t0.elapsed() < Duration::from_secs(10), "j1 never started");
        std::thread::sleep(Duration::from_millis(25));
    }
    let j2 = client.submit(&tiny_job()).unwrap();
    let full = client.submit(&tiny_job()).unwrap_err().to_string();
    assert!(full.contains("queue_full"), "{full}");

    // result before done → not_done; cancel running → not_cancellable.
    let e = client.result(&j1).unwrap_err().to_string();
    assert!(e.contains("not_done"), "{e}");
    let e = client.cancel(&j1).unwrap_err().to_string();
    assert!(e.contains("not_cancellable"), "{e}");

    // Queued jobs cancel cleanly — once.
    client.cancel(&j2).unwrap();
    assert_eq!(client.status(&j2).unwrap().state, "cancelled");
    let e = client.cancel(&j2).unwrap_err().to_string();
    assert!(e.contains("not_cancellable"), "{e}");

    // Subscribing to a terminal job yields its state immediately, and the
    // connection then keeps serving requests.
    client.subscribe(&j2).unwrap();
    let state = client.read_json_line().unwrap().unwrap();
    assert_eq!(state.get("stream").and_then(Json::as_str), Some("state"));
    assert_eq!(state.get("state").and_then(Json::as_str), Some("cancelled"));
    assert!(client.ping().is_ok(), "stream must hand the connection back");

    assert_eq!(client.wait(&j1, Duration::from_secs(60)).unwrap().state, "done");
    client.shutdown().unwrap();
    assert_eq!(daemon.wait_exit(Duration::from_secs(30)).code(), Some(0));
    assert_journal_valid(&dir);

    // Restart on the same dir: terminal states and results survive.
    let mut daemon = spawn_daemon(&dir, &[], &[]);
    let mut client = Client::connect_retry(&daemon.addr, Duration::from_secs(5)).unwrap();
    let jobs = client.jobs().unwrap();
    assert_eq!(jobs.len(), 2);
    assert_eq!((jobs[0].id.as_str(), jobs[0].state.as_str()), ("j1", "done"));
    assert_eq!((jobs[1].id.as_str(), jobs[1].state.as_str()), ("j2", "cancelled"));
    let (csv, _) = client.result("j1").unwrap();
    assert!(csv.starts_with("cell,"));
    client.shutdown().unwrap();
    assert_eq!(daemon.wait_exit(Duration::from_secs(30)).code(), Some(0));
    let _ = std::fs::remove_dir_all(&dir);
}
