//! Crash-recovery e2e: kill the daemon mid-job (deterministic coordinator
//! crash injection), restart it on the same directory, and require the
//! job to complete with artifacts byte-identical to a direct CLI run —
//! no lost jobs, no duplicated jobs.

mod serve_common;

use mkor::serve::Client;
use mkor::sweep::dispatch::COORD_EXIT_AFTER_ENV;
use mkor::util::json::Json;
use serve_common::{acceptance_job, assert_journal_valid, read, reference_artifacts, spawn_daemon, tmp};
use std::time::Duration;

#[test]
fn daemon_killed_mid_job_resumes_after_restart_with_identical_bytes() {
    let dir = tmp("recovery");
    let (ref_csv, ref_json) = reference_artifacts(&dir);
    let serve_dir = dir.join("daemon");

    // Daemon A: the sweep coordinator (the runner thread) hard-exits the
    // whole process once 2 of the 9 cells have streamed back.
    let mut daemon_a = spawn_daemon(&serve_dir, &[], &[(COORD_EXIT_AFTER_ENV, "2")]);
    let job = {
        let mut client = Client::connect_retry(&daemon_a.addr, Duration::from_secs(10)).unwrap();
        client.submit(&acceptance_job()).unwrap()
    };
    assert_eq!(job, "j1");
    let status = daemon_a.wait_exit(Duration::from_secs(120));
    assert_eq!(status.code(), Some(101), "the injected crash must fire, not a clean exit");
    assert!(
        serve_dir.join("jobs/j1/workers/coord-died.once").exists(),
        "crash sentinel missing: the daemon died for some other reason"
    );
    // Mid-job death: results were never merged.
    assert!(!serve_dir.join("jobs/j1/sweep.csv").exists());

    // Daemon B on the same directory: replays the journal, re-queues j1,
    // recovers the finished cells from the worker scratch files and runs
    // only the rest.
    let mut daemon_b = spawn_daemon(&serve_dir, &[], &[]);
    let mut client = Client::connect_retry(&daemon_b.addr, Duration::from_secs(10)).unwrap();
    let view = client.wait("j1", Duration::from_secs(300)).unwrap();
    assert_eq!(view.state, "done", "detail: {:?}", view.detail);

    // Exactly one job — restarting must not duplicate or drop it.
    let jobs = client.jobs().unwrap();
    assert_eq!(jobs.len(), 1, "{jobs:?}");
    let (csv, json) = client.result("j1").unwrap();
    assert_eq!(csv, ref_csv, "recovered artifacts must match the direct CLI run");
    assert_eq!(json, ref_json);

    client.shutdown().unwrap();
    assert_eq!(daemon_b.wait_exit(Duration::from_secs(60)).code(), Some(0));
    assert_journal_valid(&serve_dir);

    // The journal tells the whole story: one submit, an interrupted
    // `running`, a `requeued` marker from daemon B, and a final `done`.
    let journal = read(&serve_dir.join("journal.jsonl"));
    let kinds: Vec<String> = journal
        .lines()
        .map(|l| Json::parse(l).unwrap().require_str("kind").unwrap().to_string())
        .collect();
    assert_eq!(kinds.iter().filter(|k| *k == "submit").count(), 1, "{kinds:?}");
    assert!(kinds.contains(&"requeued".to_string()), "{kinds:?}");
    let states: Vec<String> = journal
        .lines()
        .map(|l| Json::parse(l).unwrap())
        .filter_map(|v| v.get("state").and_then(Json::as_str).map(str::to_string))
        .collect();
    assert_eq!(states.last().map(String::as_str), Some("done"), "{states:?}");
    let _ = std::fs::remove_dir_all(&dir);
}
