//! Concurrency e2e against a live daemon: N clients submit the same
//! 3×3 acceptance sweep concurrently, every job's artifacts must be
//! byte-identical to a direct `mkor sweep --jobs 1 --deterministic` run,
//! and a client killed mid-subscription must not disturb anyone else.

mod serve_common;

use mkor::serve::Client;
use mkor::util::json::Json;
use serve_common::{acceptance_job, assert_journal_valid, reference_artifacts, spawn_daemon, tmp};
use std::time::Duration;

#[test]
fn concurrent_clients_get_reference_identical_artifacts() {
    let dir = tmp("e2e");
    let (ref_csv, ref_json) = reference_artifacts(&dir);
    assert_eq!(ref_csv.trim().lines().count(), 1 + 9, "{ref_csv}");

    let serve_dir = dir.join("daemon");
    let mut daemon = spawn_daemon(&serve_dir, &[], &[]);
    let addr = daemon.addr.clone();

    // Three clients race the same submission; the daemon runs the jobs
    // FIFO on one runner.
    let submitters: Vec<_> = (0..3)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || -> (usize, String, String) {
                let mut client =
                    Client::connect_retry(&addr, Duration::from_secs(10)).unwrap();
                let job = client.submit(&acceptance_job()).unwrap();
                let view = client.wait(&job, Duration::from_secs(300)).unwrap();
                assert_eq!(view.state, "done", "client {i}, {job}: {:?}", view.detail);
                let (csv, json) = client.result(&job).unwrap();
                (i, csv, json)
            })
        })
        .collect();

    // A fourth client subscribes to the earliest job, reads at least one
    // stream line, then vanishes without saying goodbye.
    let killer = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut client = Client::connect_retry(&addr, Duration::from_secs(10)).unwrap();
            // j1 exists as soon as any submitter got its ack; retry until.
            let t0 = std::time::Instant::now();
            while client.status("j1").is_err() {
                assert!(t0.elapsed() < Duration::from_secs(30), "j1 never appeared");
                std::thread::sleep(Duration::from_millis(20));
            }
            client.subscribe("j1").unwrap();
            let first = client.read_json_line().unwrap().expect("at least one stream line");
            assert_eq!(
                first.get("stream").and_then(Json::as_str),
                Some("state"),
                "stream opens with the current state: {first}"
            );
            // Hard drop: no unsubscribe, no shutdown — the socket just dies.
            drop(client);
        })
    };
    killer.join().unwrap();

    for handle in submitters {
        let (i, csv, json) = handle.join().unwrap();
        assert_eq!(csv, ref_csv, "client {i}: CSV differs from the direct CLI run");
        assert_eq!(json, ref_json, "client {i}: JSON differs from the direct CLI run");
    }

    // Exactly the three submitted jobs exist, all done — the killed
    // subscriber neither added nor broke anything.
    let mut client = Client::connect_retry(&addr, Duration::from_secs(10)).unwrap();
    let jobs = client.jobs().unwrap();
    assert_eq!(jobs.len(), 3, "{jobs:?}");
    for job in &jobs {
        assert_eq!(job.state, "done", "{job:?}");
    }

    client.shutdown().unwrap();
    assert_eq!(daemon.wait_exit(Duration::from_secs(60)).code(), Some(0));
    assert_journal_valid(&serve_dir);
    let _ = std::fs::remove_dir_all(&dir);
}
