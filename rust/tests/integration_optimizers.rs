//! Integration: every optimizer in the suite trains every proxy workload
//! through the full coordinator (workers + ring all-reduce + phases).

use mkor::experiments::convergence::{run_convergence, RunOpts, TaskKind};

fn assert_trains(task: &TaskKind, opt: &str, lr: f32, min_improvement: f64) {
    let opts = RunOpts {
        lr,
        steps: 80,
        workers: 2,
        eval_every: 0,
        hidden: vec![64, 32],
        seed: 99,
        ..Default::default()
    };
    let r = run_convergence(task, opt, &opts);
    assert!(!r.diverged, "{opt} diverged");
    let first = r.losses[0];
    let last = r.final_loss();
    assert!(
        last < first * min_improvement,
        "{opt}: loss {first:.4} -> {last:.4}, expected < {min_improvement} ratio"
    );
}

#[test]
fn all_optimizers_train_images() {
    for opt in mkor::optim::ALL_OPTIMIZERS {
        let lr = match *opt {
            "adam" | "lamb" => 0.01,
            _ => 0.05,
        };
        assert_trains(&TaskKind::Images, opt, lr, 0.85);
    }
}

#[test]
fn all_optimizers_train_text() {
    let task = TaskKind::TextClass { feat_dim: 64, vocab: 64 };
    for opt in mkor::optim::ALL_OPTIMIZERS {
        let lr = match *opt {
            "adam" | "lamb" => 0.01,
            _ => 0.25,
        };
        assert_trains(&task, opt, lr, 0.97);
    }
}

#[test]
fn second_order_methods_train_autoencoder() {
    for opt in ["mkor", "mkor-h", "kfac", "eva", "sngd"] {
        assert_trains(&TaskKind::Autoencoder, opt, 0.05, 0.8);
    }
}

#[test]
fn mkor_tracks_sgd_on_anisotropic_glue_task() {
    // Contract test, not a race: on a low-rank ill-conditioned task at a
    // conservative LR, MKOR must train stably (no divergence, factors
    // finite) and stay within a small factor of SGD's loss. Whether the
    // rank-1 recurrence *accelerates* convergence is workload-dependent
    // (it amplifies the running mean-gradient direction — see the module
    // docs of optim::mkor) and is measured by the Figure 2/6 benches, not
    // asserted here.
    use mkor::data::classification::TaskConfig;
    let mut cfg = TaskConfig::new("aniso", 96, 4);
    cfg.intrinsic_rank = 6;
    cfg.separation = 1.5;
    cfg.train = 2048;
    cfg.seed = 123;
    let task = TaskKind::Glue(cfg);
    let mut opts = RunOpts {
        lr: 0.02,
        steps: 150,
        eval_every: 0,
        hidden: vec![64],
        seed: 7,
        ..Default::default()
    };
    opts.inv_freq = Some(5);
    let mkor = run_convergence(&task, "mkor", &opts);
    let sgd = run_convergence(&task, "sgd", &opts);
    assert!(!mkor.diverged && !sgd.diverged);
    assert!(mkor.final_loss() < mkor.losses[0] * 0.5, "mkor barely trained");
    assert!(
        mkor.final_loss() <= sgd.final_loss() * 3.0,
        "mkor {:.4} vs sgd {:.4}: divergence-scale gap",
        mkor.final_loss(),
        sgd.final_loss()
    );
}

#[test]
fn mkor_h_switches_and_keeps_training() {
    let task = TaskKind::Images;
    let opts = RunOpts {
        lr: 0.05,
        steps: 250,
        eval_every: 0,
        hidden: vec![64, 32],
        seed: 17,
        ..Default::default()
    };
    let r = run_convergence(&task, "mkor-h", &opts);
    assert!(!r.diverged);
    // After 250 steps on a saturating task the hybrid should have stopped
    // paying for second-order sync at some point: sync bytes stop growing.
    assert!(r.final_loss() < r.losses[0]);
}

#[test]
fn sync_byte_ordering_matches_table1() {
    // MKOR (bf16 rank-1) < Eva (fp32 rank-1) < KFAC (factors) on the same
    // run length and model.
    let task = TaskKind::Images;
    let mut opts = RunOpts {
        lr: 0.05,
        steps: 50,
        eval_every: 0,
        hidden: vec![64, 32],
        seed: 5,
        ..Default::default()
    };
    opts.inv_freq = Some(10);
    let mkor = run_convergence(&task, "mkor", &opts);
    let eva = run_convergence(&task, "eva", &opts);
    let kfac = run_convergence(&task, "kfac", &opts);
    assert!(mkor.sync_bytes < eva.sync_bytes, "{} vs {}", mkor.sync_bytes, eva.sync_bytes);
    assert!(eva.sync_bytes < kfac.sync_bytes, "{} vs {}", eva.sync_bytes, kfac.sync_bytes);
}
