//! Integration tests for the OptimizerSpec registry, through the public
//! API only: parse/print round-trips for every optimizer, build() honoring
//! overrides (observable via the sync cadence of the built optimizer), and
//! spec introspection on boxed optimizers.

use mkor::linalg::{ops, Matrix};
use mkor::model::{Activation, Capture, Dense, LayerShape};
use mkor::optim::{OptimizerSpec, ALL_OPTIMIZERS};
use mkor::util::timer::PhaseTimer;
use mkor::util::Rng;

/// One non-default spec string per optimizer (every optimizer in
/// `ALL_OPTIMIZERS` must appear).
fn nondefault_specs() -> Vec<(&'static str, String)> {
    ALL_OPTIMIZERS
        .iter()
        .map(|&name| {
            let s = match name {
                "sgd" => "sgd:momentum=0.8".to_string(),
                "adam" => "adam:beta1=0.85,beta2=0.98,eps=1e-7,wd=0.01".to_string(),
                "lamb" => "lamb:beta1=0.88,wd=0.05".to_string(),
                "kfac" => "kfac:f=7,gamma=0.9,damping=0.003,cov_freq=2,rescale=false".to_string(),
                "sngd" => "sngd:f=4,damping=0.6,momentum=0.85".to_string(),
                "eva" => "eva:damping=0.02,beta=0.9,f=3".to_string(),
                "mkor" => "mkor:f=25,gamma=0.9,backend=lamb,half=none,epsilon=64,zeta=0.25,\
                           backend.beta1=0.92,backend.wd=0.01"
                    .to_string(),
                "mkor-h" => "mkor-h:f=15,backend=adam,backend.eps=1e-8,switch_ratio=0.25,\
                             min_steps=30"
                    .to_string(),
                other => panic!("nondefault_specs has no entry for `{other}`"),
            };
            (name, s)
        })
        .collect()
}

#[test]
fn every_optimizer_round_trips_with_nondefault_hyperparameters() {
    for (name, s) in nondefault_specs() {
        let spec = OptimizerSpec::parse(&s).unwrap_or_else(|e| panic!("{s}: {e}"));
        assert_eq!(spec.name(), name);
        let canon = spec.canonical();
        assert_ne!(canon, name, "`{s}` must print its non-default keys");
        let re = OptimizerSpec::parse(&canon).unwrap_or_else(|e| panic!("{canon}: {e}"));
        assert_eq!(re, spec, "parse(print(spec)) != spec for `{s}` via `{canon}`");
        // Display and canonical agree.
        assert_eq!(format!("{spec}"), canon);
    }
}

#[test]
fn built_optimizers_expose_the_spec_that_built_them() {
    let shapes = [LayerShape::new(8, 6), LayerShape::new(6, 3)];
    for (_, s) in nondefault_specs() {
        let spec = OptimizerSpec::parse(&s).unwrap();
        let opt = spec.build(&shapes);
        assert_eq!(opt.spec(), spec, "spec() introspection for `{s}`");
        // The introspected spec's canonical string re-parses to the same
        // configuration — the reproducibility contract of run records.
        let re = OptimizerSpec::parse(&opt.spec().canonical()).unwrap();
        assert_eq!(re, spec);
    }
}

fn toy_capture(shape: LayerShape, b: usize, rng: &mut Rng) -> Capture {
    let a = Matrix::randn(shape.d_in, b, 1.0, rng);
    let g = Matrix::randn(shape.d_out, b, 1.0, rng);
    let mut dw = ops::matmul_nt(&g, &a);
    dw.scale(1.0 / b as f32);
    let db = vec![0.0; shape.d_out];
    Capture { a, g, dw, db }
}

#[test]
fn build_honors_inv_freq_override() {
    // `mkor:f=25` must actually factor every 25 steps: second-order sync
    // bytes appear exactly at t = 0, 25, 50 over 51 steps.
    let shapes = [LayerShape::new(6, 6)];
    let spec = OptimizerSpec::parse("mkor:f=25").unwrap();
    let mut opt = spec.build(&shapes);
    let mut rng = Rng::new(5);
    let mut layers = vec![Dense::init(shapes[0], Activation::Linear, &mut rng)];
    let cap = toy_capture(shapes[0], 8, &mut rng);
    let mut timer = PhaseTimer::new();
    let mut factor_steps = Vec::new();
    for t in 0..51 {
        opt.step(&mut layers, std::slice::from_ref(&cap), 0.001, &mut timer);
        if opt.sync_bytes_last_step() > 0 {
            factor_steps.push(t);
        }
    }
    assert_eq!(factor_steps, vec![0, 25, 50]);
}

#[test]
fn build_honors_half_sync_override() {
    // `half=none` doubles the rank-1 sync payload vs the bf16 default.
    let shapes = [LayerShape::new(64, 64)];
    let mut rng = Rng::new(6);
    let mut layers = vec![Dense::init(shapes[0], Activation::Linear, &mut rng)];
    let cap = toy_capture(shapes[0], 4, &mut rng);
    let mut timer = PhaseTimer::new();

    let mut full = OptimizerSpec::parse("mkor:half=none").unwrap().build(&shapes);
    full.step(&mut layers, std::slice::from_ref(&cap), 0.001, &mut timer);
    let mut bf16 = OptimizerSpec::parse("mkor").unwrap().build(&shapes);
    bf16.step(&mut layers, std::slice::from_ref(&cap), 0.001, &mut timer);
    assert_eq!(full.sync_bytes_last_step(), (64 + 64) * 4);
    assert_eq!(bf16.sync_bytes_last_step(), (64 + 64) * 2);
}

#[test]
fn nested_backend_keys_round_trip_through_built_optimizers() {
    // `backend.*` keys survive parse → build → spec() → canonical →
    // re-parse, i.e. a run record of a backend-tuned MKOR reproduces it.
    let shapes = [LayerShape::new(8, 6)];
    for s in [
        "mkor:backend=adam,backend.beta1=0.95,backend.beta2=0.98",
        "mkor:backend=lamb,backend.eps=1e-8,backend.wd=0.05",
        "mkor-h:backend=adam,backend.beta1=0.85,switch_ratio=0.3",
    ] {
        let spec = OptimizerSpec::parse(s).unwrap_or_else(|e| panic!("{s}: {e}"));
        let opt = spec.build(&shapes);
        assert_eq!(opt.spec(), spec, "spec() introspection for `{s}`");
        let canon = opt.spec().canonical();
        assert!(canon.contains("backend."), "`{canon}` lost the nested keys");
        assert_eq!(OptimizerSpec::parse(&canon).unwrap(), spec, "via `{canon}`");
    }
}

#[test]
fn unknown_names_and_keys_report_valid_choices() {
    let msg = OptimizerSpec::parse("newton").unwrap_err().to_string();
    for name in ALL_OPTIMIZERS {
        assert!(msg.contains(name), "`{msg}` should name `{name}`");
    }
    let msg = OptimizerSpec::parse("sngd:gamma=0.9").unwrap_err().to_string();
    assert!(msg.contains("gamma") && msg.contains("damping"), "{msg}");
}
