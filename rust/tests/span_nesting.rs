//! Nested-span acceptance: the begin/end guard semantics the whole
//! profiling story rests on, asserted end to end.
//!
//! Part 1 pins the guard mechanics on hand-built spans: begin/end pairs
//! share one `span` id, out-of-order drops leave siblings' parent chains
//! intact, and cross-thread handoff via `span_under` parents work on a
//! fresh thread under the spawner's span. Part 2 runs a real data-parallel
//! MKOR trainer and asserts the structural claim the walkthrough makes:
//! every `gemm` / `allreduce` / `inverse_update` leaf carries a `parent`
//! resolving through a phase span (forward / backward / allreduce /
//! factor / precond / update / broadcast) to a root `step` or `eval`
//! span, and heartbeats fire on the 10-step cadence. Parts 3 and 4 feed
//! the same trace through the Chrome exporter (balanced, deterministic
//! B/E pairs) and the self-diff regression gate (clean at defaults, every
//! row trips at `--max-regress -100`).
//!
//! One `#[test]` fn owns the whole flow: the sink is process-global, so
//! splitting install → run → finish across tests in this binary would race.

use mkor::coordinator::{Target, TrainerBuilder};
use mkor::data::classification::{Dataset, TaskConfig};
use mkor::model::{Activation, Mlp};
use mkor::obs::{self, EventKind, TraceDiff, TraceEvent};
use mkor::util::json::Json;
use mkor::util::Rng;
use std::collections::BTreeMap;

/// Phase spans that may parent leaf events inside a step or eval.
const PHASES: &[&str] =
    &["forward", "backward", "allreduce", "factor", "precond", "update", "broadcast", "eval"];

fn begins(events: &[TraceEvent]) -> BTreeMap<u64, &TraceEvent> {
    events.iter().filter(|e| e.kind == EventKind::SpanBegin).map(|e| (e.span, e)).collect()
}

fn name_of(ev: &TraceEvent) -> &str {
    ev.fields.get("name").and_then(Json::as_str).expect("span markers carry a name")
}

fn tid_of(ev: &TraceEvent) -> u64 {
    ev.fields.get("tid").and_then(Json::as_f64).expect("span markers carry a tid") as u64
}

/// Walk `parent` links from a leaf event to the root span, returning the
/// chain of span names outermost-last.
fn parent_chain<'a>(
    leaf: &TraceEvent,
    spans: &BTreeMap<u64, &'a TraceEvent>,
) -> Vec<&'a str> {
    let mut chain = Vec::new();
    let mut cursor = leaf.parent;
    while let Some(id) = cursor {
        let span =
            spans.get(&id).copied().unwrap_or_else(|| panic!("dangling parent {id} on {leaf:?}"));
        chain.push(name_of(span));
        cursor = span.parent;
        assert!(chain.len() <= 16, "parent cycle reached from {leaf:?}");
    }
    chain
}

#[test]
fn spans_nest_across_drops_threads_and_a_real_training_run() {
    let dir = std::env::temp_dir().join(format!("mkor-span-nesting-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    // ---- part 1: guard semantics on hand-built spans -------------------
    let guard_trace = dir.join("guards.jsonl");
    obs::install(&guard_trace).unwrap();
    let (a_id, b_id, c_id);
    {
        let a = obs::span::span("a");
        a_id = a.id().expect("armed guards carry ids");
        let b = obs::span::span("b");
        b_id = b.id().unwrap();
        assert_eq!(obs::span::current(), Some(b_id));
        // Out-of-order drop: `a` closes while `b` stays open, and the
        // stack removal must not disturb what nests next.
        drop(a);
        assert_eq!(obs::span::current(), Some(b_id), "b survives a's early close");
        let c = obs::span::span("c");
        c_id = c.id().unwrap();
        // Cross-thread handoff: fresh threads start with empty stacks, so
        // the parent is passed explicitly, as the trainer does per shard.
        std::thread::scope(|s| {
            s.spawn(|| {
                assert_eq!(obs::span::current(), None, "fresh thread, empty stack");
                let w = obs::span::span_under("w", Some(b_id));
                let leaf = obs::span::span("leaf"); // nests under w via this thread's stack
                assert_eq!(obs::span::current(), leaf.id());
            });
        });
    }
    obs::finish().unwrap().unwrap();

    let log = obs::read_trace(&guard_trace).unwrap();
    assert!(!log.torn_tail);
    let opened = begins(&log.events);
    let closed: BTreeMap<u64, &TraceEvent> =
        log.events.iter().filter(|e| e.kind == EventKind::SpanEnd).map(|e| (e.span, e)).collect();
    assert_eq!(opened.len(), 5, "a b c w leaf");
    assert_eq!(
        opened.keys().collect::<Vec<_>>(),
        closed.keys().collect::<Vec<_>>(),
        "every begin has exactly one end sharing its span id"
    );
    for (id, begin) in &opened {
        let end = closed[id];
        assert_eq!(name_of(begin), name_of(end));
        assert_eq!(begin.parent, end.parent, "parent resolved at begin, reused at end");
        let secs = end.secs().expect("span ends are timed");
        assert!(secs.is_finite() && secs >= 0.0);
    }
    let by_name: BTreeMap<&str, &TraceEvent> =
        opened.values().map(|e| (name_of(e), *e)).collect();
    assert_eq!(by_name["a"].parent, None, "a is a root span");
    assert_eq!(by_name["a"].span, a_id);
    assert_eq!(by_name["b"].parent, Some(a_id));
    assert_eq!(by_name["c"].parent, Some(b_id), "c parents under b, not the closed a");
    assert_eq!(by_name["c"].span, c_id);
    assert_eq!(by_name["w"].parent, Some(b_id), "explicit cross-thread handoff");
    assert_eq!(by_name["leaf"].parent, Some(by_name["w"].span));
    assert_eq!(tid_of(by_name["w"]), tid_of(by_name["leaf"]), "same spawned thread");
    assert_ne!(tid_of(by_name["w"]), tid_of(by_name["a"]), "distinct trace track");

    // ---- part 2: a real traced training run ----------------------------
    // Layers wide enough that forward/backward/precond GEMMs cross the
    // engine's dispatch threshold and emit `gemm` leaves; f=2 guarantees
    // inverse updates; 2 workers make the ring collective run.
    let mut cfg = TaskConfig::new("t", 128, 3);
    cfg.train = 256;
    cfg.test = 64;
    cfg.seed = 11;
    let ds = Dataset::generate(cfg);
    let mut rng = Rng::new(11);
    let model = Mlp::new(&[128, 160, 3], Activation::Relu, &mut rng);
    let mut trainer = TrainerBuilder::new(model)
        .optimizer_str("mkor:f=2")
        .unwrap()
        .constant_lr(0.01)
        .workers(2)
        .build();
    let batches = ds.epoch_batches(256, 0);
    assert_eq!(batches.len(), 1);
    let batch = &batches[0];

    let run_trace = dir.join("trainer.jsonl");
    obs::install(&run_trace).unwrap();
    for _ in 0..12 {
        trainer.step(&batch.x, &Target::Labels(batch.labels.clone())).expect("diverged");
    }
    trainer.evaluate(&batch.x, &Target::Labels(batch.labels.clone()));
    obs::finish().unwrap().unwrap();

    let run = obs::read_trace(&run_trace).unwrap();
    assert!(!run.torn_tail);
    let spans = begins(&run.events);
    let count = |k: EventKind| run.events.iter().filter(|e| e.kind == k).count();

    // Every step event nests directly under its own `step` span.
    let steps: Vec<&TraceEvent> =
        run.events.iter().filter(|e| e.kind == EventKind::Step).collect();
    assert_eq!(steps.len(), 12);
    let mut step_roots = std::collections::BTreeSet::new();
    for ev in &steps {
        let parent = ev.parent.expect("step events nest under their step span");
        assert_eq!(name_of(spans[&parent]), "step");
        assert!(step_roots.insert(parent), "one step span per step");
    }
    // 12 steps × 2 workers, handed off onto fresh shard threads.
    let spans_named = |n: &str| spans.values().filter(|e| name_of(e) == n).count();
    assert_eq!(spans_named("step"), 12);
    assert_eq!(spans_named("forward"), 24);
    assert_eq!(spans_named("backward"), 24);
    assert_eq!(spans_named("allreduce"), 12);
    assert_eq!(spans_named("eval"), 1);
    assert!(spans_named("factor") > 0, "f=2 over 12 steps must open factor spans");

    // The acceptance walkthrough's claim: every leaf resolves through a
    // phase span to a root `step` (or `eval`) span.
    for kind in [EventKind::Gemm, EventKind::Allreduce, EventKind::InverseUpdate] {
        assert!(count(kind) > 0, "{kind:?} must appear in this workload");
        for leaf in run.events.iter().filter(|e| e.kind == kind) {
            let chain = parent_chain(leaf, &spans);
            let innermost = chain.first().copied().unwrap_or("<root>");
            assert!(PHASES.contains(&innermost), "{kind:?} under {innermost:?}: {leaf:?}");
            let root = chain.last().copied().unwrap();
            assert!(root == "step" || root == "eval", "{kind:?} rooted at {root:?}");
            match kind {
                EventKind::Allreduce => assert_eq!(innermost, "allreduce"),
                EventKind::InverseUpdate => assert_eq!(innermost, "factor"),
                _ => {}
            }
        }
    }

    // Heartbeats on the 10-step cadence: t = 0 and t = 10, with the
    // liveness fields `mkor tail` renders.
    let beats: Vec<&TraceEvent> =
        run.events.iter().filter(|e| e.kind == EventKind::Heartbeat).collect();
    let beat_steps: Vec<f64> =
        beats.iter().map(|e| e.fields.get("step").and_then(Json::as_f64).unwrap()).collect();
    assert_eq!(beat_steps, vec![0.0, 10.0]);
    for beat in &beats {
        for key in ["steps_per_sec", "loss_ema", "state_bytes"] {
            let v = beat.fields.get(key).and_then(Json::as_f64);
            assert!(v.is_some_and(|v| v.is_finite()), "heartbeat missing {key}");
        }
    }
    assert_eq!(
        beats[0].fields.get("steps_per_sec").and_then(Json::as_f64),
        Some(0.0),
        "first beacon has no prior mark to rate against"
    );

    // ---- part 3: Chrome export over the same events --------------------
    let chrome = obs::chrome_trace_json(&run.events);
    assert_eq!(
        chrome.to_string(),
        obs::chrome_trace_json(&run.events).to_string(),
        "export is deterministic over the same events"
    );
    let rows = chrome.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
    let ph_count = |ph: &str| {
        rows.iter().filter(|r| r.get("ph").and_then(Json::as_str) == Some(ph)).count()
    };
    assert_eq!(ph_count("B"), ph_count("E"), "every duration slice opens and closes");
    assert_eq!(ph_count("B"), spans.len());
    let tree = obs::render_span_tree(&run.events);
    for phase in ["step", "forward", "allreduce"] {
        assert!(tree.contains(phase), "span tree missing {phase}:\n{tree}");
    }

    // ---- part 4: the self-diff regression gate -------------------------
    let diff = TraceDiff::of_traces(&run.events, &run.events);
    assert!(!diff.rows.is_empty());
    assert!(diff.regressions(50.0).is_empty(), "a run never regresses against itself");
    assert_eq!(
        diff.regressions(-100.0).len(),
        diff.rows.len(),
        "an impossible threshold trips every row (the CI smoke's inverted gate)"
    );

    let _ = std::fs::remove_dir_all(&dir);
}
