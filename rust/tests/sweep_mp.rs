//! Multi-process sweep e2e through the real `mkor` binary: `--workers N`
//! must produce byte-identical deterministic CSV/JSON artifacts to an
//! in-process `--jobs 1` run — including after a worker is killed
//! mid-batch (re-dispatch) and after the whole coordinator dies and the
//! sweep is re-run with `--resume` (cross-process recovery from the
//! worker result files).

use mkor::experiments::convergence::{RunOpts, TaskKind};
use mkor::sweep::dispatch::{write_batch_file, WORKER_EXIT_AFTER_ENV};
use mkor::sweep::SweepGrid;
use std::path::{Path, PathBuf};
use std::process::Command;

const BIN: &str = env!("CARGO_BIN_EXE_mkor");

/// The 3×3 acceptance grid (f × damping).
const SPECS: &str = "kfac:f={5,10,50},damping={0.01,0.03,0.1}";

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mkor-mp-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The shared `mkor sweep` invocation: tiny cells, deterministic
/// artifacts. Every run in this file layers flags on top of these.
fn sweep_cmd(csv: &Path, json: &Path) -> Command {
    let mut cmd = Command::new(BIN);
    cmd.args([
        "sweep",
        "--specs",
        SPECS,
        "--task",
        "images",
        "--steps",
        "4",
        "--cell-workers",
        "1",
        "--batch",
        "16",
        "--hidden",
        "16",
        "--eval-every",
        "2",
        "--deterministic",
    ]);
    cmd.arg("--out").arg(csv).arg("--json").arg(json);
    cmd
}

fn run_ok(cmd: &mut Command) -> String {
    let out = cmd.output().expect("spawning mkor");
    let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
    assert!(
        out.status.success(),
        "mkor failed ({:?}):\n--- stdout ---\n{stdout}\n--- stderr ---\n{}",
        out.status,
        String::from_utf8_lossy(&out.stderr)
    );
    stdout
}

fn read(path: &Path) -> String {
    std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

/// Single-process reference artifacts for the acceptance grid.
fn reference(dir: &Path) -> (String, String) {
    let csv = dir.join("ref.csv");
    let json = dir.join("ref.json");
    run_ok(sweep_cmd(&csv, &json).args(["--jobs", "1", "--quiet"]));
    (read(&csv), read(&json))
}

#[test]
fn two_workers_match_jobs1_byte_for_byte() {
    let dir = tmp("clean");
    let (ref_csv, ref_json) = reference(&dir);
    assert_eq!(ref_csv.trim().lines().count(), 1 + 9, "{ref_csv}");

    let csv = dir.join("mp.csv");
    let json = dir.join("mp.json");
    run_ok(sweep_cmd(&csv, &json).args(["--workers", "2", "--quiet"]));
    assert_eq!(read(&csv), ref_csv, "CSV must not depend on the fan-out mode");
    assert_eq!(read(&json), ref_json, "JSON must not depend on the fan-out mode");
    // Full records crossed the process boundary: loss series present.
    assert!(read(&json).contains("\"loss\""));
    // Scratch is cleaned up after a fully successful sweep.
    assert!(!dir.join("mp.csv.workers").exists());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn killed_worker_is_redispatched_and_artifacts_stay_identical() {
    let dir = tmp("kill");
    let (ref_csv, _) = reference(&dir);

    // Crash injection: the first worker exits hard after one cell; its
    // sentinel file keeps the re-dispatched batch alive.
    let csv = dir.join("killed.csv");
    let json = dir.join("killed.json");
    let scratch = dir.join("scratch-kill");
    let stdout = run_ok(
        sweep_cmd(&csv, &json)
            .args(["--workers", "2", "--keep-worker-files"])
            .arg("--worker-dir")
            .arg(&scratch)
            .env(WORKER_EXIT_AFTER_ENV, "1"),
    );
    assert!(
        scratch.join("worker-died.once").exists(),
        "the injected worker death must actually have fired"
    );
    assert!(
        stdout.contains("re-dispatching"),
        "coordinator must report the re-dispatch:\n{stdout}"
    );
    assert_eq!(
        read(&csv),
        ref_csv,
        "a killed worker must not change the merged artifact"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_recovers_worker_results_across_process_boundaries() {
    let dir = tmp("resume");
    let (ref_csv, ref_json) = reference(&dir);

    // Manufacture the scratch state a killed coordinator leaves behind:
    // one worker completed the first 4 cells (results in its .jsonl), the
    // CSV was never written. The grid and run options mirror sweep_cmd's
    // flags exactly, so the resume keys line up.
    let task = TaskKind::Images;
    let grid = SweepGrid::parse(SPECS, &task, 0).unwrap();
    assert_eq!(grid.len(), 9);
    let run = RunOpts {
        lr: 0.1,
        steps: 4,
        workers: 1,
        batch: 16,
        seed: 0,
        eval_every: 2,
        hidden: vec![16],
        ..Default::default()
    };
    let scratch = dir.join("scratch-resume");
    std::fs::create_dir_all(&scratch).unwrap();
    let batch = scratch.join("cells-dead-0.json");
    write_batch_file(&batch, &grid, &[0, 1, 2, 3], &run).unwrap();
    let mut worker = Command::new(BIN);
    worker
        .arg("sweep-worker")
        .arg("--cells-json")
        .arg(&batch)
        .arg("--out")
        .arg(scratch.join("out-dead-0.jsonl"));
    run_ok(&mut worker);

    // `--resume` scans the leftover worker files, skips those 4 cells,
    // and dispatches only the missing 5 — same bytes as a straight run.
    let csv = dir.join("resumed.csv");
    let json = dir.join("resumed.json");
    let stdout = run_ok(
        sweep_cmd(&csv, &json)
            .args(["--workers", "2", "--resume"])
            .arg("--worker-dir")
            .arg(&scratch),
    );
    let skipped = stdout.matches("skipped (ok in prior report)").count();
    assert_eq!(skipped, 4, "exactly the recovered cells skip:\n{stdout}");
    assert!(stdout.contains("(4 reused)"), "{stdout}");
    assert_eq!(read(&csv), ref_csv, "resumed CSV must match the straight run");
    assert_eq!(
        read(&json),
        ref_json,
        "worker files carry full records, so even the JSON loss series survive a resume"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
