//! End-to-end smoke: a few full training steps through the artifact
//! runtime (tiny preset). Never skipped: when `artifacts/` is absent the
//! tiny sim preset is generated into a temp dir (with an explicit NOTE);
//! set `MKOR_REQUIRE_ARTIFACTS=1` — CI does — to fail instead, proving
//! the committed generator ran.

use mkor::data::text::{MlmBatchGen, TextConfig};
use mkor::runtime::xla_trainer::{init_params, XlaTrainer, XlaTrainerConfig};
use mkor::runtime::ArtifactBundle;
use mkor::util::Rng;
use std::path::Path;

fn load_tiny() -> ArtifactBundle {
    // Cargo runs tests with the package root as cwd, so this is the
    // checked-in `artifacts/` directory `mkor artifacts` writes.
    let dir = Path::new("artifacts");
    if dir.join("tiny/meta.json").is_file() {
        return ArtifactBundle::load(dir, "tiny").expect("artifacts/tiny exists but failed to load");
    }
    if std::env::var("MKOR_REQUIRE_ARTIFACTS").ok().as_deref() == Some("1") {
        panic!(
            "MKOR_REQUIRE_ARTIFACTS=1 but artifacts/tiny is missing — \
             run `mkor artifacts` (target/release/mkor artifacts --out artifacts) first"
        );
    }
    eprintln!(
        "NOTE: artifacts/ missing; generating the tiny sim preset in a temp dir \
         (run `mkor artifacts` to use a persistent bundle)"
    );
    // Unique per call: tests in one binary run in parallel and must not
    // race each other's half-written preset files.
    static GEN: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let n = GEN.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let tmp = std::env::temp_dir().join(format!("mkor-artifacts-{}-{n}", std::process::id()));
    mkor::runtime::sim::write_preset(&tmp, "tiny").expect("generating tiny preset");
    ArtifactBundle::load(&tmp, "tiny").expect("loading generated tiny preset")
}

#[test]
fn tiny_preset_trains_and_improves() {
    let bundle = load_tiny();
    let vocab = bundle.meta.vocab;
    let seq = bundle.meta.seq_len;
    let per_worker = bundle.meta.batch;
    let mut rng = Rng::new(1);
    let params = init_params(&bundle.meta, &mut rng);
    let cfg = XlaTrainerConfig {
        workers: 2,
        lr: 0.1,
        inv_freq: 5,
        ..Default::default()
    };
    let mut trainer = XlaTrainer::new(bundle, params, cfg);
    let mut gen = MlmBatchGen::new(
        TextConfig { vocab, seed: 1, ..Default::default() },
        seq,
        0.15,
        2,
    );
    let mut losses = Vec::new();
    for _ in 0..12 {
        let batch = gen.next_tokens(per_worker * 2);
        losses.push(trainer.step(&batch).expect("step"));
    }
    // Initial loss ≈ ln(vocab); training must improve it noticeably.
    assert!(losses[0] > (vocab as f64).ln() - 1.0);
    let tail = losses[9..].iter().sum::<f64>() / 3.0;
    assert!(
        tail < losses[0] - 0.05,
        "no improvement: first {} tail {}",
        losses[0],
        tail
    );
    assert!(losses.iter().all(|l| l.is_finite()));
    // Rank-1 sync happened on factor steps (t=0,5,10) and was bf16-sized.
    let sync: usize = trainer.record.steps.iter().map(|s| s.sync_comm_bytes).sum();
    assert!(sync > 0);
    // Eval path works too.
    let eval = gen.next_tokens(per_worker);
    let el = trainer.evaluate(&eval).expect("eval");
    assert!(el.is_finite());
}

#[test]
fn hybrid_switch_engages_on_plateau() {
    let bundle = load_tiny();
    let vocab = bundle.meta.vocab;
    let seq = bundle.meta.seq_len;
    let per_worker = bundle.meta.batch;
    let mut rng = Rng::new(3);
    let params = init_params(&bundle.meta, &mut rng);
    // Aggressive switch ratio: once the early fast improvement slows to
    // half its EMA peak, the hybrid must fall back. (A plateau from step 0
    // never switches by design — the rule needs an observed peak first.)
    let cfg = XlaTrainerConfig {
        workers: 1,
        lr: 0.15,
        inv_freq: 5,
        hybrid_switch_ratio: Some(0.8),
        ..Default::default()
    };
    let mut trainer = XlaTrainer::new(bundle, params, cfg);
    let mut gen = MlmBatchGen::new(
        TextConfig { vocab, seed: 3, ..Default::default() },
        seq,
        0.15,
        4,
    );
    for _ in 0..60 {
        let batch = gen.next_tokens(per_worker);
        trainer.step(&batch).expect("step");
        if trainer.switched() {
            break;
        }
    }
    assert!(trainer.switched(), "MKOR-H never fell back to first-order");
}
