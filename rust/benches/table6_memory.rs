//! Table 6 — per-GPU memory (GB) for MKOR / KFAC / LAMB / SGD on
//! BERT-Large pre-training and ResNet-50.
//!
//! Totals are model + gradients + optimizer state + an activation-memory
//! estimate (sequence/spatial working set), so they are comparable to the
//! paper's absolute figures; the load-bearing comparison is the ordering
//! and the MKOR-vs-KFAC ratio.

use mkor::bench_utils::Table;
use mkor::costmodel::complexity::{model_step_cost, OptimizerKind};
use mkor::model::specs::{self, ModelSpec};
use std::path::Path;

/// Rough activation working set: effective batch × Σ layer outputs × 4B ×
/// 2 (forward + retained for backward).
fn activation_bytes(spec: &ModelSpec) -> f64 {
    let sum_out: usize = spec.layers.iter().map(|l| l.d_out).sum();
    2.0 * spec.effective_batch as f64 * sum_out as f64 * 4.0
}

fn total_gb(kind: OptimizerKind, spec: &ModelSpec) -> f64 {
    let params = spec.params() as f64;
    let model = params * 4.0; // fp32 master weights
    let grads = params * 4.0;
    let opt = model_step_cost(kind, spec).state_bytes;
    (model + grads + opt + activation_bytes(spec)) / 1e9
}

fn main() {
    println!("=== Table 6: per-GPU memory (GB) ===\n");
    let bert = specs::bert_large();
    let rn = specs::resnet50();
    let mut t =
        Table::new(&["Model", "MKOR", "KFAC/KAISA", "LAMB", "SGD", "paper (MKOR/KFAC/LAMB|SGD)"]);
    t.row(&[
        "ResNet-50".into(),
        format!("{:.2}", total_gb(OptimizerKind::Mkor, &rn)),
        format!("{:.2}", total_gb(OptimizerKind::Kfac, &rn)),
        format!("{:.2}", total_gb(OptimizerKind::Lamb, &rn)),
        format!("{:.2}", total_gb(OptimizerKind::Sgd, &rn)),
        "3.88 / 5.83 / - | 3.01".into(),
    ]);
    t.row(&[
        "BERT-Large".into(),
        format!("{:.2}", total_gb(OptimizerKind::Mkor, &bert)),
        format!("{:.2}", total_gb(OptimizerKind::Kfac, &bert)),
        format!("{:.2}", total_gb(OptimizerKind::Lamb, &bert)),
        format!("{:.2}", total_gb(OptimizerKind::Sgd, &bert)),
        "23.34 / 29.97 / 12.80 | -".into(),
    ]);
    println!("{}", t.render());
    let _ = t.save_csv(Path::new("results/table6_memory.csv"));

    let mkor = total_gb(OptimizerKind::Mkor, &bert);
    let kfac = total_gb(OptimizerKind::Kfac, &bert);
    let lamb = total_gb(OptimizerKind::Lamb, &bert);
    println!(
        "BERT ratios — KFAC/MKOR: {:.2} (paper 1.28), MKOR/LAMB: {:.2} (paper 1.82)",
        kfac / mkor,
        mkor / lamb
    );
    println!(
        "shape to check: SGD < MKOR < KFAC on both models; MKOR trims\n\
         KFAC's overhead by roughly the paper's ~1.3-1.5x."
    );
}
