//! Figure 3 — per-step time breakdown (factor computation / precondition /
//! weight update) per optimizer, on BERT-Large-shaped and ResNet-50-shaped
//! layers.
//!
//! Two views: (a) *measured* phase times of the Rust optimizer
//! implementations on a representative layer of each model (scaled dims),
//! and (b) the calibrated cost model's breakdown at full paper scale.

use mkor::bench_utils::{fmt_secs, Table};
use mkor::collective::ClusterModel;
use mkor::costmodel::complexity::OptimizerKind;
use mkor::costmodel::timing::{step_time, DeviceModel};
use mkor::linalg::{ops, Matrix};
use mkor::model::specs;
use mkor::model::{Activation, Capture, Dense, LayerShape};
use mkor::util::timer::PhaseTimer;
use mkor::util::Rng;
use std::path::Path;

fn measured(opt_name: &str, shape: LayerShape, b: usize, steps: usize) -> (f64, f64, f64) {
    let shapes = [shape];
    let mut rng = Rng::new(3);
    let mut layers = vec![Dense::init(shape, Activation::Linear, &mut rng)];
    let mut opt = mkor::optim::OptimizerSpec::parse(opt_name)
        .expect("optimizer spec")
        .build(&shapes);
    let mut timer = PhaseTimer::new();
    for _ in 0..steps {
        let a = Matrix::randn(shape.d_in, b, 1.0, &mut rng);
        let g = Matrix::randn(shape.d_out, b, 1.0, &mut rng);
        let mut dw = ops::matmul_nt(&g, &a);
        dw.scale(1.0 / b as f32);
        let cap = Capture { a, g, dw, db: vec![0.0; shape.d_out] };
        opt.step(&mut layers, std::slice::from_ref(&cap), 1e-4, &mut timer);
    }
    let n = steps as f64;
    (
        timer.total_secs("factor") / n,
        timer.total_secs("precond") / n,
        timer.total_secs("update") / n,
    )
}

fn main() {
    println!("=== Figure 3: per-step optimizer time breakdown ===\n");
    let opts = ["sgd", "lamb", "eva", "mkor", "sngd", "kfac"];

    println!("(a) measured on scaled layers (20 steps, averages include stale-factor steps)\n");
    let mut t = Table::new(&[
        "Model layer",
        "Optimizer",
        "factor/step",
        "precond/step",
        "update/step",
    ]);
    // BERT-like layer (d=768, transformer effective batch 512 tokens) and
    // ResNet-like layer (d=512, batch 128).
    for (label, shape, b) in [
        ("BERT-ish 768x768 b=512", LayerShape::new(768, 768), 512usize),
        ("ResNet-ish 512x512 b=128", LayerShape::new(512, 512), 128usize),
    ] {
        for opt in opts {
            let (f, p, u) = measured(opt, shape, b, 20);
            t.row(&[
                label.into(),
                opt.into(),
                fmt_secs(f),
                fmt_secs(p),
                fmt_secs(u),
            ]);
        }
    }
    println!("{}", t.render());
    let _ = t.save_csv(Path::new("results/fig3_breakdown_measured.csv"));

    println!("(b) cost model at paper scale (factor-update step shown)\n");
    let dev_a = DeviceModel::a100();
    let dev_v = DeviceModel::v100();
    let cl_a = ClusterModel::polaris_a100();
    let cl_v = ClusterModel::mist_v100();
    let mut t2 = Table::new(&[
        "Model",
        "Optimizer",
        "factor",
        "precond",
        "update",
        "grad comm",
        "2nd-order sync",
    ]);
    for (model, spec, samples, dev, cl, workers) in [
        ("BERT-Large (64xA100)", specs::bert_large(), 8usize, &dev_a, &cl_a, 64usize),
        ("ResNet-50 (64xV100)", specs::resnet50(), 32, &dev_v, &cl_v, 64),
    ] {
        for opt in opts {
            let kind = OptimizerKind::parse(opt).unwrap();
            let st = step_time(kind, &spec, samples, workers, dev, cl, true);
            t2.row(&[
                model.into(),
                kind.label().into(),
                fmt_secs(st.factor),
                fmt_secs(st.precond),
                fmt_secs(st.update),
                fmt_secs(st.grad_comm),
                fmt_secs(st.sync_comm),
            ]);
        }
    }
    println!("{}", t2.render());
    let _ = t2.save_csv(Path::new("results/fig3_breakdown_model.csv"));
    println!(
        "shape to check (paper Fig. 3): first-order rows spend only on update;\n\
         KAISA's factor bar dominates and grows from ResNet to BERT; HyLo's\n\
         kernel inversion is comparable to KAISA on BERT (b=batch*seq);\n\
         MKOR's factor bar is negligible on both."
    );
}
