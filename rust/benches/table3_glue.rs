//! Tables 3/4 — GLUE classification fine-tuning: per-task metric, average,
//! and end-to-end time/speedup per optimizer.
//!
//! The eight GLUE tasks are Gaussian-mixture proxies of graded difficulty
//! (DESIGN.md §3). Step budgets follow the paper's ratios (1563 : 1500 :
//! 600 : 1000) scaled by 1/5 so the bench stays fast; time columns come
//! from the paper-scale cost model like Table 2.

use mkor::bench_utils::Table;
use mkor::collective::ClusterModel;
use mkor::costmodel::complexity::OptimizerKind;
use mkor::costmodel::timing::amortized_step_time;
use mkor::costmodel::timing::DeviceModel;
use mkor::data::classification::glue_proxy_suite;
use mkor::experiments::convergence::{run_convergence, RunOpts, TaskKind};
use mkor::model::specs;
use std::path::Path;

fn main() {
    println!("=== Tables 3/4: GLUE-proxy fine-tuning suite ===\n");
    let scale = 5usize; // paper steps / proxy steps
    // (label, optimizer, f, proxy steps, paper row: iters/time/speedup/avg)
    let entries: [(&str, &str, Option<usize>, usize, &str); 6] = [
        ("LAMB", "lamb", None, 1563 / scale, "1563 / 7.97h / 1.00x / .8023"),
        ("KAISA", "kfac", Some(50), 1563 / scale, "1563 / 8.93h / 0.89x / .796"),
        ("MKOR-1500", "mkor", Some(10), 1500 / scale, "1500 / 7.88h / 1.01x / .8214"),
        ("MKOR-600", "mkor", Some(10), 600 / scale, "600 / 3.10h / 2.57x / .8078"),
        ("MKOR-H-600", "mkor-h", Some(10), 600 / scale, "600 / 3.10h / 2.57x / .811"),
        ("Eva", "eva", None, 1000 / scale, "1000 / 5.24h / 1.52x / .809"),
    ];

    let suite = glue_proxy_suite(64, 3);
    let spec = specs::bert_large();
    let dev = DeviceModel::a100();
    let cl = ClusterModel::polaris_a100();

    let mut t = Table::new(&[
        "Optimizer",
        "steps",
        "avg metric (8 tasks)",
        "time @paper scale",
        "speedup",
        "paper (iters/time/speedup/avg)",
    ]);
    let mut detail = Table::new(&[
        "Optimizer",
        "task",
        "metric",
    ]);
    let mut lamb_time = None;
    for (label, opt, f, steps, paper) in entries {
        let mut sum = 0.0;
        for cfg in &suite {
            let opts = RunOpts {
                lr: if opt == "lamb" { 0.02 } else { 0.08 },
                steps,
                inv_freq: f,
                eval_every: steps.max(1),
                hidden: vec![64],
                seed: 5,
                ..Default::default()
            };
            let r = run_convergence(&TaskKind::Glue(cfg.clone()), opt, &opts);
            let m = r.final_metric().unwrap_or(0.0);
            sum += m;
            detail.row(&[label.into(), cfg.name.clone(), format!("{m:.3}")]);
        }
        let avg = sum / suite.len() as f64;
        let kind = OptimizerKind::parse(opt).unwrap();
        let sstep = amortized_step_time(kind, &spec, 8, 64, &dev, &cl, f.unwrap_or(10)).total();
        let time = steps as f64 * scale as f64 * sstep;
        if label == "LAMB" {
            lamb_time = Some(time);
        }
        let speed = lamb_time.map_or("-".into(), |lt| format!("{:.2}x", lt / time));
        t.row(&[
            label.into(),
            (steps * scale).to_string(),
            format!("{avg:.4}"),
            mkor::bench_utils::fmt_secs(time),
            speed,
            paper.into(),
        ]);
    }
    println!("{}", t.render());
    println!("{}", detail.render());
    let _ = t.save_csv(Path::new("results/table3_glue.csv"));
    let _ = detail.save_csv(Path::new("results/table4_glue_per_task.csv"));
    println!(
        "shape to check: MKOR-1500 is the best average; MKOR/MKOR-H at 600\n\
         steps stay within ~1 point of LAMB-1563 while being ~2.5x faster;\n\
         KAISA underperforms at equal steps."
    );
}
