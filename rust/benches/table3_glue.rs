//! Tables 3/4 — GLUE classification fine-tuning: per-task metric, average,
//! and end-to-end time/speedup per optimizer.
//!
//! The eight GLUE tasks are Gaussian-mixture proxies of graded difficulty
//! (DESIGN.md §3). Step budgets follow the paper's ratios (1563 : 1500 :
//! 600 : 1000) scaled by 1/5 so the bench stays fast; time columns come
//! from the paper-scale cost model like Table 2.
//!
//! Each optimizer row is one spec template run over the whole task suite
//! by the sweep engine (`SweepGrid::for_tasks` + `run_sweep`): the 8
//! per-task runs fan out in parallel and merge in task order, replacing
//! the hand-rolled per-task loop this bench used to carry.

use mkor::bench_utils::Table;
use mkor::collective::ClusterModel;
use mkor::costmodel::complexity::OptimizerKind;
use mkor::costmodel::timing::amortized_step_time;
use mkor::costmodel::timing::DeviceModel;
use mkor::data::classification::glue_proxy_suite;
use mkor::experiments::convergence::{RunOpts, TaskKind};
use mkor::model::specs;
use mkor::sweep::{run_sweep, SweepGrid, SweepOptions};
use std::path::Path;

fn main() {
    println!("=== Tables 3/4: GLUE-proxy fine-tuning suite ===\n");
    let scale = 5usize; // paper steps / proxy steps
    // (label, spec template, cost-model name, f, lr, proxy steps,
    // paper row: iters/time/speedup/avg). The gamma=0.9 keys keep the MKOR
    // factor momentum the proxy harness has always used for short runs.
    let entries: [(&str, &str, &str, usize, f32, usize, &str); 6] = [
        ("LAMB", "lamb", "lamb", 10, 0.02, 1563 / scale, "1563 / 7.97h / 1.00x / .8023"),
        ("KAISA", "kfac:f=50", "kfac", 50, 0.08, 1563 / scale, "1563 / 8.93h / 0.89x / .796"),
        (
            "MKOR-1500",
            "mkor:f=10,gamma=0.9",
            "mkor",
            10,
            0.08,
            1500 / scale,
            "1500 / 7.88h / 1.01x / .8214",
        ),
        (
            "MKOR-600",
            "mkor:f=10,gamma=0.9",
            "mkor",
            10,
            0.08,
            600 / scale,
            "600 / 3.10h / 2.57x / .8078",
        ),
        (
            "MKOR-H-600",
            "mkor-h:f=10,gamma=0.9",
            "mkor-h",
            10,
            0.08,
            600 / scale,
            "600 / 3.10h / 2.57x / .811",
        ),
        ("Eva", "eva", "eva", 10, 0.08, 1000 / scale, "1000 / 5.24h / 1.52x / .809"),
    ];

    let suite = glue_proxy_suite(64, 3);
    let tasks: Vec<TaskKind> = suite.iter().map(|cfg| TaskKind::Glue(cfg.clone())).collect();
    let spec = specs::bert_large();
    let dev = DeviceModel::a100();
    let cl = ClusterModel::polaris_a100();
    let jobs = std::thread::available_parallelism().map_or(1, |n| n.get());

    let mut t = Table::new(&[
        "Optimizer",
        "steps",
        "avg metric (8 tasks)",
        "time @paper scale",
        "speedup",
        "paper (iters/time/speedup/avg)",
    ]);
    let mut detail = Table::new(&["Optimizer", "task", "metric"]);
    let mut lamb_time = None;
    for (label, template, opt, f, lr, steps, paper) in entries {
        // One engine sweep: this optimizer's template over all 8 tasks.
        let grid = SweepGrid::for_tasks(template, &tasks, 5)
            .unwrap_or_else(|e| panic!("{label} grid: {e}"));
        let opts = SweepOptions {
            jobs,
            run: RunOpts {
                lr,
                steps,
                eval_every: steps.max(1),
                hidden: vec![64],
                seed: 5,
                ..Default::default()
            },
            verbose: false,
        };
        let report = run_sweep(&grid, &opts);
        let mut sum = 0.0;
        for (cfg, cell) in suite.iter().zip(&report.cells) {
            let m = cell
                .record
                .as_ref()
                .and_then(|r| r.steps.iter().rev().find_map(|s| s.eval_metric))
                .unwrap_or(0.0);
            sum += m;
            detail.row(&[label.into(), cfg.name.clone(), format!("{m:.3}")]);
        }
        let avg = sum / suite.len() as f64;
        let kind = OptimizerKind::parse(opt).unwrap();
        let sstep = amortized_step_time(kind, &spec, 8, 64, &dev, &cl, f).total();
        let time = steps as f64 * scale as f64 * sstep;
        if label == "LAMB" {
            lamb_time = Some(time);
        }
        let speed = lamb_time.map_or("-".into(), |lt| format!("{:.2}x", lt / time));
        t.row(&[
            label.into(),
            (steps * scale).to_string(),
            format!("{avg:.4}"),
            mkor::bench_utils::fmt_secs(time),
            speed,
            paper.into(),
        ]);
    }
    println!("{}", t.render());
    println!("{}", detail.render());
    let _ = t.save_csv(Path::new("results/table3_glue.csv"));
    let _ = detail.save_csv(Path::new("results/table4_glue_per_task.csv"));
    println!(
        "shape to check: MKOR-1500 is the best average; MKOR/MKOR-H at 600\n\
         steps stay within ~1 point of LAMB-1563 while being ~2.5x faster;\n\
         KAISA underperforms at equal steps."
    );
}
