//! Figure 4b — convergence vs inversion frequency, with seed error bars.
//!
//! A pure sweep-engine wrapper: `mkor:f={...} x seed=0..3` against
//! `kfac:f={...} x seed=0..3` on the paper's own Figure-4 workload (the
//! denoising autoencoder), reporting mean ± std of the final loss per
//! (optimizer, f) over seeds. Fresher factors (small f) should give
//! equal-or-lower loss in the same budget — and only MKOR can afford f=1.

use mkor::bench_utils::Table;
use mkor::experiments::convergence::{RunOpts, TaskKind};
use mkor::sweep::{run_sweep, CellResult, CellStatus, SweepGrid, SweepOptions};
use mkor::util::stats;
use std::path::Path;

const FS: [usize; 6] = [1, 5, 10, 25, 50, 100];
const SEEDS: usize = 3;

fn mean_std(cells: &[CellResult]) -> String {
    if cells.iter().any(|c| c.status != CellStatus::Ok) {
        return "D".to_string(); // at least one seed diverged/panicked
    }
    let losses: Vec<f64> = cells.iter().filter_map(CellResult::final_loss).collect();
    let s = stats::summarize(&losses);
    format!("{:.5} ± {:.5}", s.mean, s.std)
}

fn main() {
    println!("=== Figure 4b: final loss vs f (mean ± std over seeds) ===\n");
    let specs = concat!(
        "mkor:gamma=0.9,f={1,5,10,25,50,100} x seed=0..3;",
        "kfac:f={1,5,10,25,50,100} x seed=0..3"
    );
    let grid = SweepGrid::parse(specs, &TaskKind::Autoencoder, 0).expect("sweep grammar");
    assert_eq!(grid.len(), 2 * FS.len() * SEEDS);
    let opts = SweepOptions {
        jobs: std::thread::available_parallelism().map_or(1, |n| n.get()),
        run: RunOpts {
            lr: 0.05,
            steps: 150,
            eval_every: 0,
            hidden: vec![128, 32, 128],
            ..Default::default()
        },
        verbose: false,
    };
    let report = run_sweep(&grid, &opts);

    // Grid order: seeds fastest, f next, template outermost.
    let (mkor_cells, kfac_cells) = report.cells.split_at(FS.len() * SEEDS);
    let mut t = Table::new(&["f", "MKOR final loss", "KAISA final loss"]);
    for (i, f) in FS.iter().enumerate() {
        let group = |cells: &[CellResult]| mean_std(&cells[i * SEEDS..(i + 1) * SEEDS]);
        t.row(&[f.to_string(), group(mkor_cells), group(kfac_cells)]);
    }
    println!("{}", t.render());
    let _ = report.save_csv(Path::new("results/fig4b_freq_sweep.csv"));
    println!(
        "shape to check (paper Fig. 4b): loss decreases (or holds) as f\n\
         shrinks, seeds agree on the ordering, and MKOR tolerates f=1."
    );
}
