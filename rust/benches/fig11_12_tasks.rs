//! Figures 11/12 — training time and accuracy curves for SGD / MKOR /
//! KAISA / HyLo on three workloads: BERT-Large-Cased/IMDB-proxy,
//! BERT-Base-Cased/SQuAD-proxy, AlexNet/CIFAR-100-proxy (§8.12: weight
//! decay zero everywhere, pure optimization comparison).

use mkor::bench_utils::Table;
use mkor::experiments::convergence::{run_convergence, RunOpts, TaskKind};
use std::path::Path;

fn main() {
    println!("=== Figures 11/12: three-workload optimizer comparison ===\n");
    let workloads: [(&str, TaskKind, f32, usize, &str); 3] = [
        (
            "IMDB-proxy (BERT-Large-Cased)",
            TaskKind::TextClass { feat_dim: 64, vocab: 64 },
            0.25,
            280,
            "MKOR 1.22x over SGD, 1.43x over HyLo",
        ),
        (
            "SQuAD-proxy (BERT-Base-Cased)",
            TaskKind::TextClass { feat_dim: 128, vocab: 128 },
            0.25,
            280,
            "MKOR 1.26x over SGD, 1.56x over HyLo",
        ),
        (
            "CIFAR-100-proxy (AlexNet)",
            TaskKind::Images,
            0.05,
            280,
            "MKOR 1.26/1.31/1.58x over HyLo-KIS/SGD/KAISA",
        ),
    ];
    // One-line optimizer specs; §8.12 runs every second-order method at
    // f=10 on these workloads.
    let opts_names = ["sgd", "mkor:f=10", "kfac:f=10", "sngd:f=10"];

    std::fs::create_dir_all("results").ok();
    let mut t = Table::new(&[
        "Workload",
        "Optimizer",
        "final loss",
        "final metric",
        "steps to 90% of best",
        "paper headline",
    ]);
    for (wname, task, lr, steps, paper) in workloads {
        let mut results = Vec::new();
        for opt in opts_names {
            let ro = RunOpts {
                lr,
                steps,
                eval_every: 14,
                hidden: vec![96, 48],
                seed: 31,
                ..Default::default()
            };
            let r = run_convergence(&task, opt, &ro);
            results.push((opt, r));
        }
        // 90%-of-best-metric threshold across optimizers on this workload.
        let best = results
            .iter()
            .filter_map(|(_, r)| r.final_metric())
            .fold(f64::NEG_INFINITY, f64::max);
        let thresh = if best > 0.0 { 0.9 * best } else { best * 1.1 };
        let mut csv = String::from("step");
        for (opt, _) in &results {
            csv.push_str(&format!(",{opt}"));
        }
        csv.push('\n');
        for s in 0..steps {
            csv.push_str(&s.to_string());
            for (_, r) in &results {
                csv.push(',');
                if let Some(l) = r.losses.get(s) {
                    csv.push_str(&format!("{l:.6}"));
                }
            }
            csv.push('\n');
        }
        let slug = wname.split_whitespace().next().unwrap().to_lowercase().replace("-proxy", "");
        std::fs::write(Path::new(&format!("results/fig11_12_{slug}.csv")), csv).unwrap();

        for (opt, r) in &results {
            t.row(&[
                wname.into(),
                opt.to_string(),
                if r.diverged { "D".into() } else { format!("{:.4}", r.final_loss()) },
                r.final_metric().map_or("-".into(), |m| format!("{m:.3}")),
                r.steps_to_metric(thresh).map_or("-".into(), |s| s.to_string()),
                paper.into(),
            ]);
        }
    }
    println!("{}", t.render());
    let _ = t.save_csv(Path::new("results/fig11_12_summary.csv"));
    println!(
        "shape to check (paper Figs. 11/12): MKOR reaches any given loss/\n\
         accuracy level in the fewest steps on all three workloads; HyLo\n\
         trails and is the most fragile."
    );
}
