//! Table 5 — learning-rate sensitivity: steps to converge for lr ∈
//! {10, 1, 0.1, 0.01} under MKOR / KAISA / HyLo / SGD on the CIFAR-proxy
//! classifier. "D" marks divergence, "*" a local-minimum plateau (ran out
//! of budget above the target), exactly like the paper's table.

use mkor::bench_utils::Table;
use mkor::experiments::convergence::{run_convergence, RunOpts, TaskKind};
use std::path::Path;

fn main() {
    println!("=== Table 5: LR sensitivity (ResNet-proxy on CIFAR-proxy) ===\n");
    let lrs = [10.0f32, 1.0, 0.1, 0.01];
    let target = 0.80; // accuracy target on the image proxy
    let budget = 400usize;

    let mut t = Table::new(&["Optimizer", "lr=10", "lr=1", "lr=0.1", "lr=0.01", "paper row"]);
    let paper = [
        ("mkor", "94 / 79 / 78 / 76"),
        ("kfac", "112 / 100 / 90 / 89*"),
        ("sngd", "D / 123* / 98 / 150*"),
        ("sgd", "D / D / 108 / 145*"),
    ];
    for (opt, paper_row) in paper {
        let mut cells = vec![opt.to_string()];
        for lr in lrs {
            let opts = RunOpts {
                lr,
                steps: budget,
                eval_every: 8,
                hidden: vec![96, 48],
                seed: 9,
                ..Default::default()
            };
            let r = run_convergence(&TaskKind::Images, opt, &opts);
            let cell = if r.diverged {
                "D".to_string()
            } else {
                match r.steps_to_metric(target) {
                    Some(s) => s.to_string(),
                    None => format!("{}*", budget), // plateau below target
                }
            };
            cells.push(cell);
        }
        cells.push(paper_row.to_string());
        t.row(&cells);
    }
    println!("{}", t.render());
    let _ = t.save_csv(Path::new("results/table5_lr_sensitivity.csv"));
    println!(
        "shape to check: MKOR converges across the widest LR range; SGD and\n\
         HyLo diverge (D) at large LRs; small LRs cost everyone steps."
    );
}
