//! Table 5 — learning-rate sensitivity, extended to the full lr × damping
//! grid, driven entirely by spec strings through the sweep engine (no
//! per-bench run loop). Steps-to-converge for lr ∈ {10, 1, 0.1, 0.01}
//! under MKOR / KAISA / HyLo / SGD on the CIFAR-proxy classifier, with a
//! damping axis for the Tikhonov-damped baselines. "D" marks divergence,
//! "*" a local-minimum plateau (ran out of budget above the target),
//! exactly like the paper's table.

use mkor::bench_utils::Table;
use mkor::experiments::convergence::{RunOpts, TaskKind};
use mkor::sweep::{run_sweep, CellResult, CellStatus, SweepGrid, SweepOptions};
use std::path::Path;

// One template per optimizer; `lr` is a reserved harness axis, `damping`
// sweeps the baselines' Tikhonov damping (MKOR's stabilizer threshold is
// its own knob and SGD has none — those rows stay lr-only).
const SPECS: &str = concat!(
    "mkor:gamma=0.9,lr={10,1,0.1,0.01};",
    "kfac:damping={0.003,0.03,0.3},lr={10,1,0.1,0.01};",
    "sngd:damping={0.1,0.3,1},lr={10,1,0.1,0.01};",
    "sgd:lr={10,1,0.1,0.01}"
);
const LRS: [f32; 4] = [10.0, 1.0, 0.1, 0.01];
const BUDGET: usize = 400;

fn cell_text(cell: &CellResult) -> String {
    match &cell.status {
        CellStatus::Diverged => "D".to_string(),
        CellStatus::Panicked(_) => "!".to_string(),
        CellStatus::Ok => match cell.converged_at() {
            Some(step) => step.to_string(),
            None => format!("{}*", BUDGET), // plateau below target
        },
    }
}

fn main() {
    println!("=== Table 5: LR × damping sensitivity (ResNet-proxy on CIFAR-proxy) ===\n");
    let grid = SweepGrid::parse(SPECS, &TaskKind::Images, 9).expect("sweep grammar");
    let opts = SweepOptions {
        jobs: std::thread::available_parallelism().map_or(1, |n| n.get()),
        run: RunOpts {
            steps: BUDGET,
            eval_every: 8,
            hidden: vec![96, 48],
            seed: 9,
            target_metric: Some(0.80), // accuracy target on the image proxy
            ..Default::default()
        },
        verbose: false,
    };
    let report = run_sweep(&grid, &opts);

    // Rows group by spec (the lr axis is not part of the spec string); the
    // grid guarantees each spec's cells appear in LRS order.
    let mut t = Table::new(&["Spec", "lr=10", "lr=1", "lr=0.1", "lr=0.01"]);
    for row in report.cells.chunks(LRS.len()) {
        let mut cells = vec![row[0].spec.clone()];
        for (cell, &lr) in row.iter().zip(&LRS) {
            assert_eq!(cell.lr, lr, "grid order drifted");
            assert_eq!(cell.spec, row[0].spec, "grid order drifted");
            cells.push(cell_text(cell));
        }
        t.row(&cells);
    }
    println!("{}", t.render());
    let _ = report.save_csv(Path::new("results/table5_lr_sensitivity.csv"));
    println!("paper reference rows (steps at lr=10/1/0.1/0.01):");
    println!("  mkor  94 / 79 / 78 / 76");
    println!("  kfac  112 / 100 / 90 / 89*");
    println!("  sngd  D / 123* / 98 / 150*");
    println!("  sgd   D / D / 108 / 145*");
    println!(
        "shape to check: MKOR converges across the widest LR range; SGD and\n\
         HyLo diverge (D) at large LRs; small LRs cost everyone steps; for\n\
         the damped baselines, mid damping is the sweet spot."
    );
}
