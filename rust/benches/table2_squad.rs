//! Table 2 — BERT-Large on SQuAD v1.1: metric, iterations, time, speedup.
//!
//! Substitution (DESIGN.md §3): steps-to-target is *measured* on the
//! text-proxy fine-tuning task; seconds-per-step at paper scale comes from
//! the calibrated cost model (BERT-Large, 64×A100, per-optimizer inversion
//! frequencies from §8.9: MKOR f=10, KAISA f=50). The product regenerates
//! the Time/Speedup columns. Paper values are printed alongside.
//!
//! The measured runs are one `run_sweep` over a single sweep string with
//! one spec template per optimizer — the per-optimizer learning rate rides
//! on the reserved `lr` axis, so the whole table is one engine fan-out
//! instead of a hand-rolled loop.

use mkor::bench_utils::Table;
use mkor::collective::ClusterModel;
use mkor::costmodel::complexity::OptimizerKind;
use mkor::costmodel::timing::{amortized_step_time, DeviceModel};
use mkor::experiments::convergence::{RunOpts, TaskKind};
use mkor::model::specs;
use mkor::sweep::{run_sweep, SweepGrid, SweepOptions};
use std::path::Path;

// (label, spec template with lr axis, cost-model name, f, paper iters,
// paper hours, paper speedup). The gamma=0.9 keys keep the MKOR factor
// momentum the proxy harness has always used for short runs.
const ENTRIES: [(&str, &str, &str, usize, u32, f64, f64); 5] = [
    ("LAMB", "lamb:lr=0.02", "lamb", 10, 1536, 7.97, 1.00),
    ("KAISA", "kfac:f=50,lr=0.3", "kfac", 50, 1000, 5.71, 1.39),
    ("MKOR", "mkor:f=10,gamma=0.9,lr=0.3", "mkor", 10, 1000, 5.25, 1.51),
    ("MKOR-H", "mkor-h:f=10,gamma=0.9,lr=0.3", "mkor-h", 10, 600, 3.10, 2.57),
    ("Eva", "eva:lr=0.3", "eva", 10, 1000, 5.24, 1.52),
];

fn main() {
    println!("=== Table 2: SQuAD-proxy fine-tune, BERT-Large at 64xA100 scale ===\n");
    let task = TaskKind::TextClass { feat_dim: 64, vocab: 64 };
    let target_loss = 3.70; // masked-token loss target (init ≈ ln 64 = 4.16)

    let spec = specs::bert_large();
    let dev = DeviceModel::a100();
    let cl = ClusterModel::polaris_a100();

    // One template per optimizer, one merged fan-out.
    let sweep_specs: Vec<&str> = ENTRIES.iter().map(|e| e.1).collect();
    let grid = SweepGrid::parse(&sweep_specs.join(";"), &task, 11)
        .unwrap_or_else(|e| panic!("table2 grid: {e}"));
    assert_eq!(grid.len(), ENTRIES.len());
    let jobs = std::thread::available_parallelism().map_or(1, |n| n.get());
    let opts = SweepOptions {
        jobs,
        run: RunOpts {
            steps: 600,
            eval_every: 10,
            hidden: vec![96],
            seed: 11,
            ..Default::default()
        },
        verbose: false,
    };
    let report = run_sweep(&grid, &opts);

    struct Row {
        label: &'static str,
        steps: Option<usize>,
        metric: f64,
        sstep: f64,
        p_iters: u32,
        p_hours: f64,
        p_speed: f64,
        diverged: bool,
    }
    let rows: Vec<Row> = ENTRIES
        .iter()
        .zip(&report.cells)
        .map(|(&(label, _, opt, f, p_iters, p_hours, p_speed), cell)| {
            let record = cell.record.as_ref().expect("cell panicked");
            let kind = OptimizerKind::parse(opt).unwrap();
            let st = amortized_step_time(kind, &spec, 8, 64, &dev, &cl, f);
            let metric = record
                .steps
                .iter()
                .rev()
                .find_map(|s| s.eval_metric)
                .unwrap_or(0.0);
            Row {
                label,
                steps: record.steps_to_loss(target_loss),
                metric,
                sstep: st.total(),
                p_iters,
                p_hours,
                p_speed,
                diverged: record.diverged,
            }
        })
        .collect();

    // Speedup normalization: LAMB row is the baseline.
    let lamb_time = rows[0].steps.map(|s| s as f64 * rows[0].sstep);
    let lamb_paper_time = rows[0].p_iters as f64 * rows[0].sstep;
    let mut t = Table::new(&[
        "Optimizer",
        "proxy metric",
        "steps to target",
        "model s/step @paper scale",
        "speedup (measured)",
        "time @paper iters (model)",
        "speedup @paper iters",
        "paper iters",
        "paper time (h)",
        "paper speedup",
    ]);
    for r in &rows {
        let time = r.steps.map(|s| s as f64 * r.sstep);
        let speed = match (&lamb_time, &time) {
            (Some(lt), Some(tt)) => format!("{:.2}x", lt / tt),
            _ => "-".into(),
        };
        t.row(&[
            r.label.to_string(),
            if r.diverged { "DIVERGED".into() } else { format!("{:.3}", r.metric) },
            r.steps.map_or("-".into(), |s| s.to_string()),
            mkor::bench_utils::fmt_secs(r.sstep),
            speed,
            mkor::bench_utils::fmt_secs(r.p_iters as f64 * r.sstep),
            format!("{:.2}x", lamb_paper_time / (r.p_iters as f64 * r.sstep)),
            r.p_iters.to_string(),
            format!("{:.2}", r.p_hours),
            format!("{:.2}x", r.p_speed),
        ]);
    }
    println!("{}", t.render());
    let _ = t.save_csv(Path::new("results/table2_squad.csv"));
    println!(
        "shape to check vs paper (speedup @paper iters column): MKOR-H > Eva/\n\
         MKOR > KAISA > LAMB — the paper's ordering, driven by our measured\n\
         per-step cost model. The measured-steps column is the honest proxy\n\
         result: on a small MLP, LAMB's trust ratio is hard to beat and the\n\
         rank-1 factor information adds little (see EXPERIMENTS.md §Fidelity)."
    );
}
