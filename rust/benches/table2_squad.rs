//! Table 2 — BERT-Large on SQuAD v1.1: metric, iterations, time, speedup.
//!
//! Substitution (DESIGN.md §3): steps-to-target is *measured* on the
//! text-proxy fine-tuning task; seconds-per-step at paper scale comes from
//! the calibrated cost model (BERT-Large, 64×A100, per-optimizer inversion
//! frequencies from §8.9: MKOR f=10, KAISA f=50). The product regenerates
//! the Time/Speedup columns. Paper values are printed alongside.

use mkor::bench_utils::Table;
use mkor::collective::ClusterModel;
use mkor::costmodel::complexity::OptimizerKind;
use mkor::costmodel::timing::{amortized_step_time, DeviceModel};
use mkor::experiments::convergence::{run_convergence, RunOpts, TaskKind};
use mkor::model::specs;
use std::path::Path;

fn main() {
    println!("=== Table 2: SQuAD-proxy fine-tune, BERT-Large at 64xA100 scale ===\n");
    let task = TaskKind::TextClass { feat_dim: 64, vocab: 64 };
    let target_loss = 3.70; // masked-token loss target (init ≈ ln 64 = 4.16)

    let spec = specs::bert_large();
    let dev = DeviceModel::a100();
    let cl = ClusterModel::polaris_a100();

    // (name, optimizer, lr, inversion frequency f, paper iters, paper hours, paper speedup)
    let entries: [(&str, &str, f32, Option<usize>, u32, f64, f64); 5] = [
        ("LAMB", "lamb", 0.02, None, 1536, 7.97, 1.00),
        ("KAISA", "kfac", 0.3, Some(50), 1000, 5.71, 1.39),
        ("MKOR", "mkor", 0.3, Some(10), 1000, 5.25, 1.51),
        ("MKOR-H", "mkor-h", 0.3, Some(10), 600, 3.10, 2.57),
        ("Eva", "eva", 0.3, None, 1000, 5.24, 1.52),
    ];

    let opts_base = RunOpts {
        steps: 600,
        eval_every: 10,
        hidden: vec![96],
        seed: 11,
        ..Default::default()
    };

    let mut rows = Vec::new();
    for (label, opt, lr, f, p_iters, p_hours, p_speed) in entries {
        let mut opts = opts_base.clone();
        opts.lr = lr;
        opts.inv_freq = f;
        let r = run_convergence(&task, opt, &opts);
        let steps = r.steps_to_loss(target_loss);
        let kind = OptimizerKind::parse(opt).unwrap();
        let st = amortized_step_time(kind, &spec, 8, 64, &dev, &cl, f.unwrap_or(10));
        let hours = steps.map(|s| {
            // Scale proxy steps to paper iteration counts via the LAMB
            // anchor (paper 1536 LAMB iters == our measured LAMB steps).
            s as f64 * st.total() / 3600.0
        });
        rows.push((label, steps, r.final_metric().unwrap_or(0.0), hours, st.total(), p_iters, p_hours, p_speed, r.diverged));
    }

    // Speedup normalization: LAMB row is the baseline.
    let lamb_time = rows[0].1.map(|s| s as f64 * rows[0].4);
    let mut t = Table::new(&[
        "Optimizer",
        "proxy metric",
        "steps to target",
        "model s/step @paper scale",
        "speedup (measured)",
        "time @paper iters (model)",
        "speedup @paper iters",
        "paper iters",
        "paper time (h)",
        "paper speedup",
    ]);
    for (label, steps, metric, _hours, sstep, p_iters, p_hours, p_speed, diverged) in &rows {
        let time = steps.map(|s| s as f64 * sstep);
        let speed = match (&lamb_time, &time) {
            (Some(lt), Some(tt)) => format!("{:.2}x", lt / tt),
            _ => "-".into(),
        };
        t.row(&[
            label.to_string(),
            if *diverged { "DIVERGED".into() } else { format!("{metric:.3}") },
            steps.map_or("-".into(), |s| s.to_string()),
            mkor::bench_utils::fmt_secs(*sstep),
            speed,
            mkor::bench_utils::fmt_secs(*p_iters as f64 * sstep),
            format!("{:.2}x", (rows[0].5 as f64 * rows[0].4) / (*p_iters as f64 * sstep)),
            p_iters.to_string(),
            format!("{p_hours:.2}"),
            format!("{p_speed:.2}x"),
        ]);
    }
    println!("{}", t.render());
    let _ = t.save_csv(Path::new("results/table2_squad.csv"));
    println!(
        "shape to check vs paper (speedup @paper iters column): MKOR-H > Eva/\n\
         MKOR > KAISA > LAMB — the paper's ordering, driven by our measured\n\
         per-step cost model. The measured-steps column is the honest proxy\n\
         result: on a small MLP, LAMB's trust ratio is hard to beat and the\n\
         rank-1 factor information adds little (see EXPERIMENTS.md §Fidelity)."
    );
}
