//! Figure 2 — training loss of BERT-Large-proxy under LAMB / KAISA / MKOR /
//! MKOR-H / Eva. Emits the loss series as CSV and prints steps-to-loss
//! milestones (the figure's qualitative content: MKOR-family curves drop
//! faster per iteration). A second cell repeats the comparison on the
//! causal-transformer proxy (`charlm`) — the workload class the paper's
//! headline claims are about.

use mkor::bench_utils::Table;
use mkor::experiments::convergence::{run_convergence, RunOpts, TaskKind};
use std::path::Path;

/// Render the milestone table + write the per-step CSV for one task cell.
fn report(curves: &[(String, Vec<f64>)], steps: usize, out: &str) {
    let init = curves
        .iter()
        .map(|(_, l)| l.first().copied().unwrap_or(f64::NAN))
        .fold(0.0f64, f64::max);
    let milestones = [0.95 * init, 0.9 * init, 0.87 * init];
    let mut t = Table::new(&[
        "Optimizer",
        "steps to 95% of init loss",
        "steps to 90%",
        "steps to 87%",
        "final loss",
    ]);
    for (label, losses) in curves {
        let fake = mkor::experiments::convergence::ConvergenceResult {
            losses: losses.clone(),
            ..Default::default()
        };
        let mut row = vec![label.clone()];
        for m in milestones {
            row.push(fake.steps_to_loss(m).map_or("-".into(), |s| s.to_string()));
        }
        row.push(format!("{:.4}", losses.last().copied().unwrap_or(f64::NAN)));
        t.row(&row);
    }
    println!("{}", t.render());

    // CSV: step, one column per optimizer.
    let mut csv = String::from("step");
    for (label, _) in curves {
        csv.push(',');
        csv.push_str(label);
    }
    csv.push('\n');
    for s in 0..steps {
        csv.push_str(&s.to_string());
        for (_, losses) in curves {
            csv.push(',');
            if let Some(l) = losses.get(s) {
                csv.push_str(&format!("{l:.6}"));
            }
        }
        csv.push('\n');
    }
    std::fs::create_dir_all("results").ok();
    std::fs::write(Path::new(out), csv).unwrap();
    println!("series written to {out}");
}

fn main() {
    println!("=== Figure 2: training-loss curves (BERT-proxy MLM) ===\n");
    let task = TaskKind::TextClass { feat_dim: 64, vocab: 64 };
    let steps = 400usize;

    // Inversion frequencies ride along in the optimizer spec strings
    // (§8.9: MKOR f=10 where KAISA needs 50).
    let entries: [(&str, &str, f32); 5] = [
        ("LAMB", "lamb", 0.02),
        ("KAISA", "kfac:f=50", 0.3),
        ("MKOR", "mkor:f=10", 0.3),
        ("MKOR-H", "mkor-h:f=10", 0.3),
        ("Eva", "eva", 0.3),
    ];

    let mut curves: Vec<(String, Vec<f64>)> = Vec::new();
    for (label, spec, lr) in entries {
        let opts = RunOpts {
            lr,
            steps,
            eval_every: 0,
            hidden: vec![96],
            seed: 21,
            ..Default::default()
        };
        let r = run_convergence(&task, spec, &opts);
        curves.push((label.to_string(), r.losses));
    }

    report(&curves, steps, "results/fig2_loss_curves.csv");
    println!(
        "shape to check (paper Fig. 2): MKOR/MKOR-H reach each loss level in\n\
         fewer iterations than KAISA and LAMB; Eva sits between.\n"
    );

    // Second cell: the causal-transformer proxy. Every capture column set
    // here is batch·seq_len wide (sequence positions fold into the batch) —
    // the regime where MKOR's O(d) factor updates pay off.
    println!("=== Figure 2 (cont.): causal-transformer proxy (charlm) ===\n");
    let task = TaskKind::CharLm { vocab: 48, seq_len: 16 };
    let steps = 150usize;
    let entries: [(&str, &str, f32); 3] =
        [("MKOR", "mkor:f=10", 0.05), ("KAISA", "kfac:f=50", 0.05), ("LAMB", "lamb", 0.01)];
    let mut curves: Vec<(String, Vec<f64>)> = Vec::new();
    for (label, spec, lr) in entries {
        let opts = RunOpts {
            lr,
            steps,
            batch: 16,
            eval_every: 0,
            hidden: Vec::new(),
            seed: 21,
            ..Default::default()
        };
        let r = run_convergence(&task, spec, &opts);
        curves.push((label.to_string(), r.losses));
    }
    report(&curves, steps, "results/fig2_charlm_loss_curves.csv");
}
