//! Table 1 — computation / memory / communication complexity per optimizer.
//!
//! Two parts: (a) the asymptotic table exactly as the paper prints it, with
//! concrete per-step numbers for BERT-Large instantiated from the cost
//! model; (b) *measured* scaling exponents of the Rust factor-update
//! implementations over d (and over b for SNGD), verifying that the code
//! actually scales as the table claims.

use mkor::bench_utils::{bench_fn, Table};
use mkor::costmodel::complexity::{model_step_cost, OptimizerKind};
use mkor::linalg::{ops, Matrix};
use mkor::model::specs;
use mkor::model::{Capture, Dense, LayerShape};
use mkor::util::timer::PhaseTimer;
use mkor::util::Rng;
use std::path::Path;

fn capture(shape: LayerShape, b: usize, rng: &mut Rng) -> Capture {
    let a = Matrix::randn(shape.d_in, b, 1.0, rng);
    let g = Matrix::randn(shape.d_out, b, 1.0, rng);
    let mut dw = ops::matmul_nt(&g, &a);
    dw.scale(1.0 / b as f32);
    Capture { a, g, dw, db: vec![0.0; shape.d_out] }
}

/// Median seconds of the *factor phase* of a fresh optimizer's first step
/// (step 0 is a factor step for every second-order method here).
fn factor_secs(opt_name: &str, d: usize, b: usize) -> f64 {
    let shapes = [LayerShape::new(d, d)];
    let mut rng = Rng::new(1);
    let cap = capture(shapes[0], b, &mut rng);
    let mut layers = vec![Dense::init(shapes[0], mkor::model::Activation::Linear, &mut rng)];
    let mut last_factor = 0.0;
    let spec = mkor::optim::OptimizerSpec::parse(opt_name).expect("optimizer spec");
    let r = bench_fn(opt_name, 0.3, || {
        let mut opt = spec.build(&shapes);
        let mut timer = PhaseTimer::new();
        opt.step(&mut layers, std::slice::from_ref(&cap), 0.0, &mut timer);
        last_factor = timer.total_secs("factor");
        last_factor
    });
    // Use the phase measurement itself (bench_fn repeats stabilize caches).
    let _ = r;
    last_factor
}

fn fit_slope(xs: &[f64], ys: &[f64]) -> f64 {
    let n = xs.len() as f64;
    let lx: Vec<f64> = xs.iter().map(|x| x.ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|y| y.max(1e-12).ln()).collect();
    let mx = lx.iter().sum::<f64>() / n;
    let my = ly.iter().sum::<f64>() / n;
    let num: f64 = lx.iter().zip(&ly).map(|(x, y)| (x - mx) * (y - my)).sum();
    let den: f64 = lx.iter().map(|x| (x - mx) * (x - mx)).sum();
    num / den
}

fn main() {
    println!("=== Table 1: complexity of the optimizer family ===\n");
    let spec = specs::bert_large();
    let mut t = Table::new(&[
        "Optimizer",
        "Computational",
        "Memory overhead",
        "Communication",
        "BERT-L factor FLOPs/step",
        "BERT-L sync/step",
        "BERT-L state",
    ]);
    for kind in [
        OptimizerKind::Mkor,
        OptimizerKind::Sngd,
        OptimizerKind::Kfac,
        OptimizerKind::Eva,
        OptimizerKind::Sgd,
        OptimizerKind::Lamb,
    ] {
        let (comp, mem, comm) = kind.asymptotics();
        let c = model_step_cost(kind, &spec);
        t.row(&[
            kind.label().into(),
            comp.into(),
            mem.into(),
            comm.into(),
            format!("{:.2e}", c.factor_flops),
            mkor::bench_utils::fmt_bytes(c.sync_bytes),
            mkor::bench_utils::fmt_bytes(c.state_bytes),
        ]);
    }
    println!("{}", t.render());
    let _ = t.save_csv(Path::new("results/table1_complexity.csv"));

    println!("=== Measured factor-phase scaling of the Rust implementations ===\n");
    let dims = [128usize, 256, 512];
    let mut t2 = Table::new(&[
        "Optimizer",
        "axis",
        "sizes",
        "times",
        "fitted exponent",
        "paper says",
    ]);
    for (name, paper) in [("mkor", "d^2"), ("kfac", "d^3")] {
        let xs: Vec<f64> = dims.iter().map(|&d| d as f64).collect();
        let ys: Vec<f64> = dims.iter().map(|&d| factor_secs(name, d, 64)).collect();
        t2.row(&[
            name.into(),
            "d".into(),
            format!("{dims:?}"),
            ys.iter()
                .map(|y| mkor::bench_utils::fmt_secs(*y))
                .collect::<Vec<_>>()
                .join(" "),
            format!("{:.2}", fit_slope(&xs, &ys)),
            paper.into(),
        ]);
    }
    let bs = [64usize, 128, 256];
    let xs: Vec<f64> = bs.iter().map(|&b| b as f64).collect();
    let ys: Vec<f64> = bs.iter().map(|&b| factor_secs("sngd", 192, b)).collect();
    t2.row(&[
        "sngd".into(),
        "b".into(),
        format!("{bs:?}"),
        ys.iter()
            .map(|y| mkor::bench_utils::fmt_secs(*y))
            .collect::<Vec<_>>()
            .join(" "),
        format!("{:.2}", fit_slope(&xs, &ys)),
        "b^3 (+ b^2 d build)".into(),
    ]);
    println!("{}", t2.render());
    let _ = t2.save_csv(Path::new("results/table1_measured_scaling.csv"));
    println!(
        "(exponents within ~±0.6 of the asymptote are expected at these sizes;\n\
         lower-order terms and caches bend the small points)"
    );
}
