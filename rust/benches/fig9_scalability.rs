//! Figure 9 — strong scaling of MKOR (vs KAISA and LAMB) on BERT-Large up
//! to 64 GPUs, from the calibrated cluster cost model, plus a *measured*
//! in-process ring all-reduce scaling check of the payload sizes involved.

use mkor::bench_utils::{bench_fn, fmt_secs, Table};
use mkor::collective::ring::allreduce_mean;
use mkor::collective::ClusterModel;
use mkor::costmodel::complexity::{model_step_cost, OptimizerKind};
use mkor::costmodel::timing::amortized_step_time;
use mkor::costmodel::timing::DeviceModel;
use mkor::model::specs;
use std::path::Path;

fn main() {
    println!("=== Figure 9: strong scaling on BERT-Large ===\n");
    let spec = specs::bert_large();
    let dev = DeviceModel::a100();
    let cl = ClusterModel::polaris_a100();
    let workers = [1usize, 4, 8, 16, 32, 64];

    let mut t = Table::new(&[
        "workers",
        "MKOR samples/s",
        "KAISA samples/s",
        "LAMB samples/s",
        "MKOR sync/step",
        "KAISA sync/step",
    ]);
    let mut csv = String::from("workers,mkor,kaisa,lamb\n");
    for w in workers {
        let thr = |kind: OptimizerKind, f: usize| {
            let st = amortized_step_time(kind, &spec, 8, w, &dev, &cl, f);
            8.0 * w as f64 / st.total()
        };
        let m = thr(OptimizerKind::Mkor, 10);
        let k = thr(OptimizerKind::Kfac, 50);
        let l = thr(OptimizerKind::Lamb, 1);
        let msync = model_step_cost(OptimizerKind::Mkor, &spec).sync_bytes;
        let ksync = model_step_cost(OptimizerKind::Kfac, &spec).sync_bytes;
        t.row(&[
            w.to_string(),
            format!("{m:.1}"),
            format!("{k:.1}"),
            format!("{l:.1}"),
            fmt_secs(cl.allreduce_time(msync as usize, w)),
            fmt_secs(cl.allreduce_time(ksync as usize, w)),
        ]);
        csv.push_str(&format!("{w},{m},{k},{l}\n"));
    }
    println!("{}", t.render());
    std::fs::create_dir_all("results").ok();
    std::fs::write(Path::new("results/fig9_scalability.csv"), csv).unwrap();

    println!(
        "measured in-process ring all-reduce (payload = MKOR rank-1 vs KFAC factors, \
         one 1024-dim layer):\n"
    );
    let mut t2 = Table::new(&["workers", "payload", "bytes/worker", "wall time"]);
    for w in [2usize, 4, 8] {
        for (label, n) in [("MKOR 2d", 2 * 1024usize), ("KFAC 4d^2", 4 * 1024 * 1024)] {
            let mut bufs: Vec<Vec<f32>> = (0..w).map(|i| vec![i as f32; n]).collect();
            let stats = allreduce_mean(&mut bufs);
            let r = bench_fn(label, 0.15, || {
                let mut bufs: Vec<Vec<f32>> = (0..w).map(|i| vec![i as f32; n]).collect();
                allreduce_mean(&mut bufs)
            });
            t2.row(&[
                w.to_string(),
                label.into(),
                mkor::bench_utils::fmt_bytes(stats.bytes_per_worker as f64),
                fmt_secs(r.median_secs),
            ]);
        }
    }
    println!("{}", t2.render());
    let _ = t2.save_csv(Path::new("results/fig9_ring_measured.csv"));
    println!(
        "shape to check (paper Fig. 9): MKOR's throughput stays near LAMB's\n\
         and keeps scaling to 64 GPUs; KAISA's flattens as its O(d^2) factor\n\
         sync grows with the worker count."
    );
}
