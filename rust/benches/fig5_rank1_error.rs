//! Figures 5 & 10 — rank-1 approximation error of the activation and
//! input-gradient covariance matrices.
//!
//! Collects covariances during proxy training, measures (i) the optimal
//! rank-1 error (Eckart–Young via power iteration) and (ii) MKOR's
//! mean-vector rank-1 error, prints the error distributions (Fig. 5) and
//! the error-vs-iteration trend (Fig. 10).

use mkor::bench_utils::Table;
use mkor::experiments::spectra::collect_spectra;
use mkor::util::stats::Histogram;
use std::path::Path;

fn main() {
    println!("=== Figures 5/10: rank-1 covariance approximation error ===\n");
    let samples = collect_spectra(61, 10, &[128, 64], 17);

    // Figure 5: error distributions per side.
    for side in ["a", "g"] {
        let mut h_opt = Histogram::new(0.0, 1.0, 10);
        let mut h_mean = Histogram::new(0.0, 1.0, 10);
        for s in samples.iter().filter(|s| s.side == side) {
            h_opt.add(s.optimal_rank1_err);
            h_mean.add(s.mean_rank1_err.min(0.9999));
        }
        let label = if side == "a" {
            "activations (right factor)"
        } else {
            "input gradients (left factor)"
        };
        println!("--- {label}: optimal rank-1 relative-error distribution ---");
        print!("{}", h_opt.ascii(40));
        println!("--- {label}: MKOR mean-vector rank-1 error distribution ---");
        print!("{}", h_mean.ascii(40));
        println!();
    }

    // Figure 10: mean error vs iteration.
    let mut t = Table::new(&[
        "step",
        "mean optimal rank-1 err",
        "mean MKOR rank-1 err",
        "mean cond(C)",
    ]);
    let steps: Vec<usize> = {
        let mut v: Vec<usize> = samples.iter().map(|s| s.step).collect();
        v.sort_unstable();
        v.dedup();
        v
    };
    for step in steps {
        let at: Vec<_> = samples.iter().filter(|s| s.step == step).collect();
        let n = at.len() as f64;
        let opt = at.iter().map(|s| s.optimal_rank1_err).sum::<f64>() / n;
        let mean = at.iter().map(|s| s.mean_rank1_err).sum::<f64>() / n;
        let cond = at
            .iter()
            .map(|s| if s.cond.is_finite() { s.cond } else { 1e12 })
            .sum::<f64>()
            / n;
        t.row(&[
            step.to_string(),
            format!("{opt:.4}"),
            format!("{mean:.4}"),
            format!("{cond:.2e}"),
        ]);
    }
    println!("{}", t.render());

    // CSV dump of every sample.
    let mut csv = String::from(
        "step,layer,side,optimal_rank1_err,mean_rank1_err,lambda_max,lambda_min,cond\n",
    );
    for s in &samples {
        csv.push_str(&format!(
            "{},{},{},{},{},{},{},{}\n",
            s.step, s.layer, s.side, s.optimal_rank1_err, s.mean_rank1_err,
            s.lambda_max, s.lambda_min, s.cond
        ));
    }
    std::fs::create_dir_all("results").ok();
    std::fs::write(Path::new("results/fig5_fig10_rank1.csv"), csv).unwrap();
    println!("samples written to results/fig5_fig10_rank1.csv");
    println!(
        "shape to check (paper Figs. 5/10): most optimal-rank-1 errors are\n\
         well below 1 (covariances are low-rank), and the error *decreases*\n\
         as training progresses (decaying eigenvalues, §8.7)."
    );
}
