//! Figure 8 — eigenvalues and condition number of the KFAC right factor
//! during training (ResNet-proxy on CIFAR-proxy): the numerical-fragility
//! evidence motivating MKOR's inversion-free design.

use mkor::bench_utils::Table;
use mkor::experiments::spectra::collect_spectra;
use std::path::Path;

fn main() {
    println!("=== Figure 8: KFAC factor spectrum during training ===\n");
    let samples = collect_spectra(81, 20, &[96, 48], 29);

    let mut t = Table::new(&[
        "step",
        "layer",
        "lambda_max (AAᵀ)",
        "lambda_min",
        "condition number",
    ]);
    for s in samples.iter().filter(|s| s.side == "a") {
        t.row(&[
            s.step.to_string(),
            s.layer.to_string(),
            format!("{:.3e}", s.lambda_max),
            format!("{:.3e}", s.lambda_min),
            if s.cond.is_finite() { format!("{:.3e}", s.cond) } else { "inf".into() },
        ]);
    }
    println!("{}", t.render());

    let conds: Vec<f64> = samples
        .iter()
        .filter(|s| s.side == "a" && s.cond.is_finite())
        .map(|s| s.cond)
        .collect();
    let geo_mean = (conds.iter().map(|c| c.ln()).sum::<f64>() / conds.len().max(1) as f64).exp();
    println!("geometric-mean condition number: {geo_mean:.3e}");

    let mut csv = String::from("step,layer,lambda_max,lambda_min,cond\n");
    for s in samples.iter().filter(|s| s.side == "a") {
        csv.push_str(&format!(
            "{},{},{},{},{}\n",
            s.step, s.layer, s.lambda_max, s.lambda_min, s.cond
        ));
    }
    std::fs::create_dir_all("results").ok();
    std::fs::write(Path::new("results/fig8_condition.csv"), csv).unwrap();
    println!("series written to results/fig8_condition.csv");
    println!(
        "shape to check (paper Fig. 8): minimum eigenvalues sit near zero so\n\
         condition numbers are huge (≥1e6) — inverting these factors without\n\
         damping is numerically hopeless, which is MKOR's motivation."
    );
}
