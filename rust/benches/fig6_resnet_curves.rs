//! Figure 6 — test accuracy vs epoch for MKOR / KAISA / SGD on the
//! ResNet-proxy image classifier (ImageNet stand-in).

use mkor::bench_utils::Table;
use mkor::experiments::convergence::{run_convergence, RunOpts, TaskKind};
use std::path::Path;

fn main() {
    println!("=== Figure 6: accuracy-vs-steps, ResNet-proxy ===\n");
    let steps = 320usize;
    let eval_every = 16usize;
    // Per-optimizer inversion frequencies as one-line spec strings.
    let entries: [(&str, &str, f32); 3] = [
        ("SGD", "sgd", 0.05),
        ("KAISA", "kfac:f=50", 0.05),
        ("MKOR", "mkor:f=10", 0.05),
    ];

    let mut curves = Vec::new();
    for (label, spec, lr) in entries {
        let opts = RunOpts {
            lr,
            steps,
            eval_every,
            hidden: vec![128, 64],
            seed: 23,
            ..Default::default()
        };
        let r = run_convergence(&TaskKind::Images, spec, &opts);
        curves.push((label, r));
    }

    let target = 0.82;
    let mut t =
        Table::new(&["Optimizer", "final acc", "steps to 82%", "paper epochs (75.9% target)"]);
    let paper = ["88 (SGD)", "54 (KAISA)", "57 (MKOR), 1.49x faster than SGD"];
    for ((label, r), p) in curves.iter().zip(paper) {
        t.row(&[
            label.to_string(),
            format!("{:.3}", r.final_metric().unwrap_or(0.0)),
            r.steps_to_metric(target).map_or("-".into(), |s| s.to_string()),
            p.into(),
        ]);
    }
    println!("{}", t.render());

    let mut csv = String::from("step");
    for (label, _) in &curves {
        csv.push_str(&format!(",{label}"));
    }
    csv.push('\n');
    let n_evals = curves[0].1.evals.len();
    for i in 0..n_evals {
        csv.push_str(&curves[0].1.evals[i].0.to_string());
        for (_, r) in &curves {
            csv.push(',');
            if let Some((_, m)) = r.evals.get(i) {
                csv.push_str(&format!("{m:.5}"));
            }
        }
        csv.push('\n');
    }
    std::fs::create_dir_all("results").ok();
    std::fs::write(Path::new("results/fig6_accuracy_curves.csv"), csv).unwrap();
    println!("series written to results/fig6_accuracy_curves.csv");
    println!(
        "shape to check (paper Fig. 6): second-order curves climb faster per\n\
         step than SGD; MKOR ≈ KAISA per step but each MKOR step is cheaper."
    );
}
