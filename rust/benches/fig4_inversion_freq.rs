//! Figure 4 — inversion-frequency sensitivity.
//!
//! (a) average iteration cost vs factor-update period f for MKOR vs KAISA —
//!     measured on the autoencoder and modeled at BERT scale;
//! (b) convergence (final loss after a fixed budget) vs f — fresher factors
//!     should help, and only MKOR can afford f=1.

use mkor::bench_utils::{fmt_secs, Table};
use mkor::collective::ClusterModel;
use mkor::costmodel::complexity::OptimizerKind;
use mkor::costmodel::timing::{amortized_step_time, DeviceModel};
use mkor::experiments::convergence::{run_convergence, RunOpts, TaskKind};
use mkor::model::specs;
use std::path::Path;

fn main() {
    println!("=== Figure 4: inversion-frequency sensitivity ===\n");
    let fs = [1usize, 5, 10, 50, 100];
    let steps = 200usize;

    let mut t = Table::new(&[
        "f",
        "MKOR s/step (measured)",
        "KAISA s/step (measured)",
        "MKOR s/step (BERT model)",
        "KAISA s/step (BERT model)",
        "MKOR final loss",
        "KAISA final loss",
    ]);
    let spec = specs::bert_large();
    let dev = DeviceModel::a100();
    let cl = ClusterModel::polaris_a100();
    for f in fs {
        let opts = RunOpts {
            lr: 0.05,
            steps,
            inv_freq: Some(f),
            eval_every: 0,
            hidden: vec![128, 32, 128],
            seed: 13,
            ..Default::default()
        };
        let rm = run_convergence(&TaskKind::Autoencoder, "mkor", &opts);
        let rk = run_convergence(&TaskKind::Autoencoder, "kfac", &opts);
        let mm = amortized_step_time(OptimizerKind::Mkor, &spec, 8, 64, &dev, &cl, f);
        let mk = amortized_step_time(OptimizerKind::Kfac, &spec, 8, 64, &dev, &cl, f);
        t.row(&[
            f.to_string(),
            fmt_secs(rm.step_secs),
            fmt_secs(rk.step_secs),
            fmt_secs(mm.total()),
            fmt_secs(mk.total()),
            format!("{:.5}", rm.final_loss()),
            format!("{:.5}", rk.final_loss()),
        ]);
    }
    println!("{}", t.render());
    let _ = t.save_csv(Path::new("results/fig4_inversion_freq.csv"));
    println!(
        "shape to check (paper Fig. 4): KAISA's average step time falls\n\
         steeply with f while MKOR's is nearly flat (a); smaller f gives\n\
         equal-or-lower loss in the same budget (b)."
    );
}
