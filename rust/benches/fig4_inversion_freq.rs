//! Figure 4 — inversion-frequency sensitivity.
//!
//! (a) average iteration cost vs factor-update period f for MKOR vs KAISA —
//!     measured on the autoencoder and modeled at BERT scale;
//! (b) convergence (final loss after a fixed budget) vs f — fresher factors
//!     should help, and only MKOR can afford f=1.
//!
//! The measured columns come from one sweep-engine run over spec strings
//! (`mkor:f={...}` / `kfac:f={...}`); the modeled columns stay analytic.
//! `fig4b_freq_sweep` adds the multi-seed version of panel (b).

use mkor::bench_utils::{fmt_secs, Table};
use mkor::collective::ClusterModel;
use mkor::costmodel::complexity::OptimizerKind;
use mkor::costmodel::timing::{amortized_step_time, DeviceModel};
use mkor::experiments::convergence::{RunOpts, TaskKind};
use mkor::model::specs;
use mkor::sweep::{run_sweep, SweepGrid, SweepOptions};
use std::path::Path;

const FS: [usize; 5] = [1, 5, 10, 50, 100];
const STEPS: usize = 200;

fn main() {
    println!("=== Figure 4: inversion-frequency sensitivity ===\n");
    // One sweep, two templates (grid order: all mkor cells, then all kfac);
    // the brace lists derive from FS so the column join below cannot drift.
    let fs_list = FS.map(|f| f.to_string()).join(",");
    let sweep_specs = format!("mkor:gamma=0.9,f={{{fs_list}}};kfac:f={{{fs_list}}}");
    let grid = SweepGrid::parse(&sweep_specs, &TaskKind::Autoencoder, 13).expect("sweep grammar");
    assert_eq!(grid.len(), 2 * FS.len());
    // Two jobs keep wall-clock contention low enough that the measured
    // s/step columns stay meaningful while still halving the sweep time.
    let opts = SweepOptions {
        jobs: 2,
        run: RunOpts {
            lr: 0.05,
            steps: STEPS,
            eval_every: 0,
            hidden: vec![128, 32, 128],
            seed: 13,
            ..Default::default()
        },
        verbose: false,
    };
    let report = run_sweep(&grid, &opts);
    let (mkor_cells, kfac_cells) = report.cells.split_at(FS.len());

    let mut t = Table::new(&[
        "f",
        "MKOR s/step (measured)",
        "KAISA s/step (measured)",
        "MKOR s/step (BERT model)",
        "KAISA s/step (BERT model)",
        "MKOR final loss",
        "KAISA final loss",
    ]);
    let spec = specs::bert_large();
    let dev = DeviceModel::a100();
    let cl = ClusterModel::polaris_a100();
    for (i, f) in FS.iter().enumerate() {
        let (rm, rk) = (&mkor_cells[i], &kfac_cells[i]);
        let steps_m = rm.steps_run().max(1) as f64;
        let steps_k = rk.steps_run().max(1) as f64;
        let mm = amortized_step_time(OptimizerKind::Mkor, &spec, 8, 64, &dev, &cl, *f);
        let mk = amortized_step_time(OptimizerKind::Kfac, &spec, 8, 64, &dev, &cl, *f);
        t.row(&[
            f.to_string(),
            fmt_secs(rm.wall_secs() / steps_m),
            fmt_secs(rk.wall_secs() / steps_k),
            fmt_secs(mm.total()),
            fmt_secs(mk.total()),
            format!("{:.5}", rm.final_loss().unwrap_or(f64::NAN)),
            format!("{:.5}", rk.final_loss().unwrap_or(f64::NAN)),
        ]);
    }
    println!("{}", t.render());
    let _ = t.save_csv(Path::new("results/fig4_inversion_freq.csv"));
    println!(
        "shape to check (paper Fig. 4): KAISA's average step time falls\n\
         steeply with f while MKOR's is nearly flat (a); smaller f gives\n\
         equal-or-lower loss in the same budget (b)."
    );
}
