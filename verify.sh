#!/usr/bin/env bash
# Tier-1 verification gate (see ROADMAP.md), plus stricter extras:
#   1. cargo build --release          — the library and the `mkor` binary
#   2. cargo test -q                  — unit + integration tests
#   3. cargo build --release --all-targets — benches/examples compile too
#   4. cargo doc --no-deps            — rustdoc gate, warnings denied
#      (broken intra-doc links and malformed doc blocks are fatal)
#   5. docs link check                — every relative markdown link in
#      README.md and docs/ must resolve to a real file
#   6. cargo fmt --check              — strict by default (the whole tree
#      is rustfmt-clean); set FMT=soft to downgrade to a warning while
#      iterating locally
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo build --release =="
cargo build --release

echo "== mkor artifacts (generate artifacts/, then require them in tests) =="
target/release/mkor artifacts --out artifacts
export MKOR_REQUIRE_ARTIFACTS=1

echo "== cargo test -q =="
cargo test -q

echo "== cargo build --release --all-targets (benches + examples) =="
cargo build --release --all-targets

echo "== cargo doc --no-deps (RUSTDOCFLAGS=-D warnings) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== docs link check (README.md, docs/*.md) =="
python3 - <<'EOF'
import os, re, sys

bad = []
files = ["README.md"] + sorted(
    os.path.join("docs", f) for f in os.listdir("docs") if f.endswith(".md")
)
link = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
for path in files:
    text = open(path, encoding="utf-8").read()
    # Strip fenced code blocks: their brackets are code, not links.
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for target in link.findall(text):
        if target.startswith(("http://", "https://", "#", "mailto:")):
            continue
        rel = target.split("#")[0]
        if rel and not os.path.exists(os.path.join(os.path.dirname(path), rel)):
            bad.append(f"{path}: broken link -> {target}")
for b in bad:
    print(b, file=sys.stderr)
if bad:
    sys.exit(1)
print(f"checked {len(files)} markdown files, all relative links resolve")
EOF

echo "== rustfmt --check rust/src/{sweep,checkpoint,linalg/engine,perf,obs,serve,model/transformer.rs} (fmt-strict modules) =="
if command -v rustfmt >/dev/null 2>&1; then
    # These subsystems postdate rustfmt adoption and stay fmt-clean
    # unconditionally — even under FMT=soft.
    rustfmt --edition 2021 --check \
        rust/src/sweep/*.rs rust/src/checkpoint/*.rs \
        rust/src/linalg/engine/*.rs rust/src/perf/*.rs rust/src/obs/*.rs \
        rust/src/serve/*.rs \
        rust/src/model/transformer.rs
else
    echo "warning: rustfmt not installed; skipping strict-module format check" >&2
fi

echo "== cargo fmt --check (repo-wide, strict) =="
if command -v rustfmt >/dev/null 2>&1; then
    if ! cargo fmt --check; then
        if [ "${FMT:-strict}" = "strict" ]; then
            echo "formatting check failed (set FMT=soft to downgrade while iterating)" >&2
            exit 1
        fi
        echo "warning: formatting differs from rustfmt (non-fatal under FMT=soft)" >&2
    fi
else
    echo "warning: rustfmt not installed; skipping format check" >&2
fi

echo "verify.sh: all gates passed"
