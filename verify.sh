#!/usr/bin/env bash
# Tier-1 verification gate (see ROADMAP.md), plus stricter extras:
#   1. cargo build --release          — the library and the `mkor` binary
#   2. cargo test -q                  — unit + integration tests
#   3. cargo build --release --all-targets — benches/examples compile too
#   4. cargo fmt --check              — soft by default (the seed tree
#      predates rustfmt enforcement); set FMT=strict to make it fatal
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo build --release --all-targets (benches + examples) =="
cargo build --release --all-targets

echo "== rustfmt --check rust/src/{sweep,checkpoint} (fmt-strict modules) =="
if command -v rustfmt >/dev/null 2>&1; then
    # The sweep/ and checkpoint/ subsystems postdate rustfmt adoption and
    # stay fmt-clean unconditionally, while the seed tree is still
    # soft-checked below.
    rustfmt --edition 2021 --check rust/src/sweep/*.rs rust/src/checkpoint/*.rs
else
    echo "warning: rustfmt not installed; skipping sweep/checkpoint format check" >&2
fi

echo "== cargo fmt --check =="
if command -v rustfmt >/dev/null 2>&1; then
    if ! cargo fmt --check; then
        if [ "${FMT:-}" = "strict" ]; then
            echo "formatting check failed (FMT=strict)" >&2
            exit 1
        fi
        echo "warning: formatting differs from rustfmt (non-fatal; FMT=strict enforces)" >&2
    fi
else
    echo "warning: rustfmt not installed; skipping format check" >&2
fi

echo "verify.sh: all gates passed"
