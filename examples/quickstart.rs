//! Quickstart: train the same classifier with MKOR and with SGD-momentum
//! and compare steps-to-target — the paper's core claim in 60 seconds,
//! no artifacts required.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use mkor::coordinator::{Target, TrainerBuilder};
use mkor::data::classification::{Dataset, TaskConfig};
use mkor::model::{Activation, Mlp};
use mkor::util::Rng;

fn run(opt_name: &str, ds: &Dataset) -> (Option<usize>, f64, f64) {
    let mut rng = Rng::new(42);
    let model = Mlp::new(&[ds.cfg.dim, 64, 32, ds.cfg.classes], Activation::Relu, &mut rng);
    let mut trainer = TrainerBuilder::new(model)
        .optimizer_str(opt_name)
        .expect("optimizer spec")
        .constant_lr(0.02)
        .workers(4)
        .target_metric(0.86)
        .run_name(format!("quickstart-{opt_name}"))
        .build();
    let test = ds.test_batch();
    let t0 = std::time::Instant::now();
    let mut steps = 0usize;
    'outer: for epoch in 0..60 {
        for b in ds.epoch_batches(64, epoch) {
            if trainer.step(&b.x, &Target::Labels(b.labels.clone())).is_none() {
                break 'outer;
            }
            steps += 1;
            if steps % 8 == 0 {
                trainer.evaluate(&test.x, &Target::Labels(test.labels.clone()));
                if trainer.converged() {
                    break 'outer;
                }
            }
        }
    }
    let (_, acc) = trainer.evaluate(&test.x, &Target::Labels(test.labels.clone()));
    let rec = trainer.finish();
    (rec.converged_at, acc.unwrap_or(0.0), t0.elapsed().as_secs_f64())
}

fn main() {
    let mut cfg = TaskConfig::new("quickstart", 64, 4);
    cfg.train = 4096;
    cfg.test = 1024;
    cfg.separation = 1.5;
    cfg.intrinsic_rank = 12; // low-rank inputs: MKOR's favourable regime
    let ds = Dataset::generate(cfg);

    println!("task: 4-class Gaussian mixture, d=64, intrinsic rank 12, target 86% acc\n");
    let mut table = mkor::bench_utils::Table::new(&[
        "Optimizer",
        "Steps to 86%",
        "Final acc",
        "Wall time",
    ]);
    for name in ["sgd", "mkor", "mkor-h"] {
        let (steps, acc, secs) = run(name, &ds);
        table.row(&[
            name.to_string(),
            steps.map_or("not reached".into(), |s| s.to_string()),
            format!("{:.3}", acc),
            mkor::bench_utils::fmt_secs(secs),
        ]);
    }
    println!("{}", table.render());
    println!("MKOR should reach the target in fewer steps than SGD —");
    println!("the steps-to-target gap is what Tables 2/3 of the paper scale up.");
}
