//! End-to-end driver: train the transformer MLM through the full
//! three-layer stack — Rust coordinator → PJRT → AOT HLO containing the
//! JAX model and the Pallas MKOR kernels — on the synthetic Markov–Zipf
//! corpus, and log the loss curve.
//!
//! ```sh
//! make artifacts
//! cargo run --release --example train_e2e -- --preset small --steps 200
//! ```
//!
//! The recorded run (EXPERIMENTS.md §E2E) uses `--preset small --steps 300
//! --workers 2`; `--preset base` is the ~100M-parameter configuration.

use mkor::cli::Args;
use mkor::data::text::{MlmBatchGen, TextConfig};
use mkor::runtime::xla_trainer::{init_params, XlaTrainer, XlaTrainerConfig};
use mkor::runtime::ArtifactBundle;
use mkor::util::Rng;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let preset = args.get_or("preset", "small");
    let steps = args.usize_or("steps", 200);
    let workers = args.usize_or("workers", 2);
    let seed = args.u64_or("seed", 0);
    let out = args.get_or("out", "results/e2e.json").to_string();

    let bundle = ArtifactBundle::load(Path::new(args.get_or("artifacts", "artifacts")), preset)?;
    println!(
        "preset `{}` on {}: {:.1}M params, {} transformer layers, {} preconditioned matrices",
        bundle.meta.preset,
        bundle.platform(),
        bundle.meta.params as f64 / 1e6,
        bundle.meta.n_layers,
        bundle.meta.factor_dims.len(),
    );

    let mut rng = Rng::new(seed);
    let params = init_params(&bundle.meta, &mut rng);
    let cfg = XlaTrainerConfig {
        workers,
        lr: args.f32_or("lr", 0.05),
        gamma: args.f32_or("gamma", 0.99),
        inv_freq: args.usize_or("inv-freq", 10),
        half_sync: true,
        hybrid_switch_ratio: if args.flag("hybrid") { Some(0.1) } else { None },
        ..Default::default()
    };
    let vocab = bundle.meta.vocab;
    let seq_len = bundle.meta.seq_len;
    let per_worker = bundle.meta.batch;
    let mut trainer = XlaTrainer::new(bundle, params, cfg);

    let mut gen = MlmBatchGen::new(
        TextConfig { vocab, seed, ..Default::default() },
        seq_len,
        0.15,
        seed ^ 0xE2E,
    );
    let eval_batch = gen.next_tokens(per_worker);

    let t0 = std::time::Instant::now();
    let mut first = None;
    for s in 0..steps {
        let batch = gen.next_tokens(per_worker * workers);
        let loss = trainer.step(&batch)?;
        first.get_or_insert(loss);
        if s % 10 == 0 || s + 1 == steps {
            println!("step {s:>5}  train loss {loss:.5}");
        }
        if (s + 1) % 50 == 0 {
            let el = trainer.evaluate(&eval_batch)?;
            println!("         eval  loss {el:.5}");
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let rec = &trainer.record;
    println!(
        "\n{} steps in {} ({} /step); loss {:.4} -> {:.4}; \
         grad comm/step {}, rank-1 sync total {}",
        steps,
        mkor::bench_utils::fmt_secs(secs),
        mkor::bench_utils::fmt_secs(secs / steps.max(1) as f64),
        first.unwrap_or(f64::NAN),
        rec.final_loss(),
        mkor::bench_utils::fmt_bytes(
            rec.steps.last().map(|r| r.grad_comm_bytes as f64).unwrap_or(0.0)
        ),
        mkor::bench_utils::fmt_bytes(
            rec.steps.iter().map(|r| r.sync_comm_bytes as f64).sum()
        ),
    );
    trainer.record.save_json(Path::new(&out))?;
    println!("loss curve written to {out}");
    Ok(())
}
