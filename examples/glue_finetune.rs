//! GLUE-proxy fine-tuning suite: all eight difficulty-graded tasks under a
//! chosen optimizer, reporting per-task metric and the average — the
//! Table 3/4 workload at example scale.
//!
//! ```sh
//! cargo run --release --example glue_finetune -- --optimizer mkor --steps 400
//! ```

use mkor::cli::Args;
use mkor::coordinator::{Target, TrainerBuilder};
use mkor::data::classification::{glue_proxy_suite, Dataset};
use mkor::model::{Activation, Mlp};
use mkor::optim::OptimizerSpec;
use mkor::util::Rng;
use std::process::exit;

fn main() {
    let args = Args::from_env();
    let opt_name = args.get_or("optimizer", "mkor");
    let steps = args.usize_or("steps", 400);
    let dim = args.usize_or("dim", 64);
    let seed = args.u64_or("seed", 0);

    // `--optimizer` accepts the full spec grammar, e.g. `mkor:f=25`.
    let spec = match OptimizerSpec::parse(opt_name) {
        Ok(spec) => spec,
        Err(e) => {
            eprintln!("error: {e}");
            exit(2);
        }
    };

    println!("fine-tuning 8 GLUE-proxy tasks with `{spec}` ({steps} steps each)\n");
    let mut table = mkor::bench_utils::Table::new(&["Task", "Accuracy", "Steps run"]);
    let mut sum = 0.0;
    for cfg in glue_proxy_suite(dim, seed) {
        let name = cfg.name.clone();
        let ds = Dataset::generate(cfg);
        let mut rng = Rng::new(seed ^ 77);
        let model = Mlp::new(&[dim, 64, ds.cfg.classes], Activation::Relu, &mut rng);
        let mut trainer = TrainerBuilder::new(model)
            .optimizer(spec.clone())
            .constant_lr(args.f32_or("lr", 0.1))
            .workers(2)
            .run_name(name.clone())
            .build();
        let mut done = 0;
        'outer: for epoch in 0..10_000 {
            for b in ds.epoch_batches(64, epoch) {
                if trainer.step(&b.x, &Target::Labels(b.labels.clone())).is_none() {
                    break 'outer;
                }
                done += 1;
                if done >= steps {
                    break 'outer;
                }
            }
        }
        let test = ds.test_batch();
        let (_, acc) = trainer.evaluate(&test.x, &Target::Labels(test.labels.clone()));
        let acc = acc.unwrap_or(0.0);
        sum += acc;
        table.row(&[name, format!("{acc:.3}"), done.to_string()]);
    }
    table.row(&["AVERAGE".into(), format!("{:.4}", sum / 8.0), String::new()]);
    println!("{}", table.render());
    println!("compare averages across optimizers — the Table 3/4 bench sweeps them all.");
}
