use mkor::bench_utils::{bench_fn, fmt_secs};
use mkor::linalg::{ops, Matrix};
use mkor::util::Rng;
fn main() {
    let mut rng = Rng::new(1);
    for d in [256usize, 512, 1024] {
        let a = Matrix::randn(d, d, 1.0, &mut rng);
        let b = Matrix::randn(d, d, 1.0, &mut rng);
        let mut c = Matrix::zeros(d, d);
        let r = bench_fn("mm", 0.4, || ops::matmul_into(&a, &b, &mut c));
        let gflops = 2.0 * (d as f64).powi(3) / r.median_secs / 1e9;
        println!("matmul d={d}: {} ({gflops:.2} GF/s)", fmt_secs(r.median_secs));
        // SM update (the MKOR factor hot path)
        let mut inv = Matrix::rand_spd(d, 0.1, &mut rng);
        let v: Vec<f32> = (0..d).map(|_| rng.gaussian_f32()).collect();
        let mut scratch = vec![0.0f32; d];
        let r = bench_fn("sm", 0.3, || {
            mkor::optim::Mkor::sm_update(&mut inv, &v, 0.99, &mut scratch)
        });
        let gb = (d as f64 * d as f64 * 4.0 * 2.0) / r.median_secs / 1e9; // read+write J
        println!("sm_update d={d}: {} ({gb:.2} GB/s effective)", fmt_secs(r.median_secs));
        inv.blend_identity(0.5); // keep bounded
    }
}
