//! Inversion-frequency study on the autoencoder (the paper's Figure 4
//! workload): how do per-step cost and convergence react to the factor
//! refresh period `f` under MKOR vs KAISA?
//!
//! ```sh
//! cargo run --release --example inversion_frequency -- --steps 150
//! ```

use mkor::cli::Args;
use mkor::coordinator::{Target, TrainerBuilder};
use mkor::data::images::{ImageConfig, ImageGen};
use mkor::model::{Activation, Mlp};
use mkor::util::Rng;

fn run(spec: &str, steps: usize, seed: u64) -> (f64, f64) {
    let mut gen = ImageGen::new(ImageConfig::default(), seed);
    let d = gen.dim();
    let mut rng = Rng::new(seed);
    let model = Mlp::new(&[d, 128, 32, 128, d], Activation::Tanh, &mut rng);
    let mut trainer = TrainerBuilder::new(model)
        .optimizer_str(spec)
        .expect("optimizer spec")
        .constant_lr(0.05)
        .workers(2)
        .run_name("invfreq")
        .build();
    let t0 = std::time::Instant::now();
    let mut last = f64::NAN;
    for _ in 0..steps {
        let b = gen.next_autoencoder_batch(64);
        if let Some(l) = trainer.step(&b.x, &Target::Dense(b.y)) {
            last = l;
        } else {
            break;
        }
    }
    (last, t0.elapsed().as_secs_f64() / steps as f64)
}

fn main() {
    let args = Args::from_env();
    let steps = args.usize_or("steps", 150);

    println!("autoencoder (3072-proxy: 256→128→32→128→256) on synthetic CIFAR-like data\n");
    let mut table = mkor::bench_utils::Table::new(&[
        "Optimizer",
        "f (refresh period)",
        "Final loss",
        "Avg step time",
    ]);
    for f in [1usize, 5, 10, 50, 100] {
        // The whole sweep is two one-line spec strings per refresh period.
        let (loss, secs) = run(&format!("mkor:f={f}"), steps, 7);
        table.row(&[
            "MKOR".into(),
            f.to_string(),
            format!("{loss:.5}"),
            mkor::bench_utils::fmt_secs(secs),
        ]);
        let (loss, secs) = run(&format!("kfac:f={f}"), steps, 7);
        table.row(&[
            "KAISA".into(),
            f.to_string(),
            format!("{loss:.5}"),
            mkor::bench_utils::fmt_secs(secs),
        ]);
    }
    println!("{}", table.render());
    println!(
        "Expected shape (paper Fig. 4): KAISA's step time falls steeply as f grows\n\
         while MKOR's is flat; smaller f (fresher factors) converges further."
    );
}
