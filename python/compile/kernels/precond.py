"""L1 Pallas kernel: tiled matmul for the preconditioning step.

ΔW = R⁻¹ · ∇W · L⁻¹ is two dense matmuls (Equation 2); on TPU they map to
the 128×128 MXU systolic array, so the kernel tiles M/N/K at 128 and
accumulates over the K grid axis in a VMEM-resident output block — the
BlockSpec below is the HBM↔VMEM schedule a CUDA implementation expresses
with threadblocks (DESIGN.md §7). The norm rescale (line 10) is two scalar
reductions; XLA fuses them with the surrounding graph, so they are left at
the jnp level.

Used inside the ``mkor_step`` artifact and also for the transformer's
dense layers in ``model.py`` so the lowered HLO genuinely contains the L1
kernels on the model's hot path.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned tiles.
BM, BN, BK = 128, 128, 128


def _matmul_kernel(a_ref, b_ref, o_ref):
    """Grid (M/BM, N/BN, K/BK); K is the innermost (sequential) axis, so the
    output tile stays resident while partial products accumulate into it."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += a_ref[...] @ b_ref[...]


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _matmul_impl(a, b):
    """C = A @ B via the tiled Pallas kernel (arbitrary shapes, padded)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"matmul shape mismatch {a.shape} @ {b.shape}"
    ap = _pad_to(_pad_to(a, BM, 0), BK, 1)
    bp = _pad_to(_pad_to(b, BK, 0), BN, 1)
    mp, kp = ap.shape
    _, np_ = bp.shape
    grid = (mp // BM, np_ // BN, kp // BK)
    out = pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((BK, BN), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, kk: (i, j)),
        interpret=True,
    )(ap, bp)
    return out[:m, :n]


@jax.custom_vjp
def matmul(a, b):
    """Differentiable Pallas matmul. The K-accumulating grid kernel has no
    JVP rule, so the VJP is supplied explicitly — and is itself two Pallas
    matmuls (dA = dC·Bᵀ, dB = Aᵀ·dC), keeping the L1 kernel on the model's
    backward path too."""
    return _matmul_impl(a, b)


def _matmul_fwd(a, b):
    return _matmul_impl(a, b), (a, b)


def _matmul_bwd(res, dc):
    a, b = res
    da = _matmul_impl(dc, b.T)
    db = _matmul_impl(a.T, dc)
    return da, db


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def precond(rinv, grad, linv):
    """ΔW = R⁻¹ ∇W L⁻¹ (two MXU-tiled matmuls)."""
    return matmul(matmul(rinv, grad), linv)


def precond_rescaled(rinv, grad, linv, eps=1e-30):
    """Preconditioning + the line-10 norm rescale."""
    delta = precond(rinv, grad, linv)
    gn = jnp.linalg.norm(grad)
    dn = jnp.linalg.norm(delta)
    scale = jnp.where(dn > eps, gn / jnp.maximum(dn, eps), 1.0)
    return delta * scale
