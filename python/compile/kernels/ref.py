"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every kernel in this package has a
pytest comparing it against the function here (hypothesis sweeps shapes and
values), and the Rust implementation of Algorithm 1 is cross-checked against
the same formulas through the AOT artifacts.
"""

import jax.numpy as jnp


def sm_update_ref(inv, v, gamma):
    """Equation 5/6 of the paper: the Sherman–Morrison-based rank-1 update
    of a factor inverse.

        J⁻¹ ← γ J⁻¹ + (1−γ) / (γ² (1 + γ(1−γ) vᵀJ⁻¹v)) (J⁻¹v)(J⁻¹v)ᵀ
    """
    u = inv @ v
    s = v @ u
    coef = (1.0 - gamma) / (gamma * gamma * (1.0 + gamma * (1.0 - gamma) * s))
    return gamma * inv + coef * jnp.outer(u, u)


def precond_ref(rinv, grad, linv):
    """Preconditioning (Equation 2 in the x @ W convention):

        ΔW = R⁻¹ · ∇W · L⁻¹   with ∇W ∈ R^{d_in×d_out}.
    """
    return rinv @ grad @ linv


def rescale_ref(delta, grad, eps=1e-30):
    """Algorithm 1 line 10: match ‖ΔW‖_F to ‖∇W‖_F."""
    gn = jnp.linalg.norm(grad)
    dn = jnp.linalg.norm(delta)
    scale = jnp.where(dn > eps, gn / jnp.maximum(dn, eps), 1.0)
    return delta * scale


def matmul_ref(a, b):
    """Plain matmul oracle for the tiled Pallas matmul."""
    return a @ b
