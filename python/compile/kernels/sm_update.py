"""L1 Pallas kernels for MKOR's Sherman–Morrison rank-1 factor update.

The update (Equations 5/6) is two O(d²) passes over the factor inverse J:

  pass 1 (``matvec``):      u = J v            — row-tiled, J read once
  scalar (host graph):      s = vᵀu,  coef = (1−γ)/(γ²(1+γ(1−γ)s))
  pass 2 (``rank1_blend``): J ← γJ + coef·uuᵀ  — row-tiled, J read+written once

Hardware adaptation (DESIGN.md §7): on a GPU this is a cuBLAS GEMV + GER.
On TPU the d×d factor streams HBM→VMEM in ``BLOCK``-row tiles; the vector
operands stay VMEM-resident across the whole grid, so total HBM traffic is
exactly 2 reads + 1 write of J per update. All kernels run under
``interpret=True`` — the CPU PJRT plugin cannot execute Mosaic custom calls;
numerics are validated through this path and TPU efficiency is estimated
analytically in ``analysis.py``.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile height. 256 rows × d≤4096 cols × 4B ≤ 4 MiB — comfortably within
# a TPU core's ~16 MiB VMEM alongside the u/v operands.
BLOCK = 256


def _pad_rows(n: int) -> int:
    return (n + BLOCK - 1) // BLOCK * BLOCK


def _matvec_kernel(j_ref, v_ref, u_ref):
    """One row-tile of u = J v."""
    u_ref[...] = j_ref[...] @ v_ref[...]


def matvec(j, v):
    """u = J v with J row-tiled through VMEM. Arbitrary d (padded)."""
    d = j.shape[0]
    dp = _pad_rows(d)
    jp = jnp.pad(j, ((0, dp - d), (0, 0)))
    grid = (dp // BLOCK,)
    u = pl.pallas_call(
        _matvec_kernel,
        out_shape=jax.ShapeDtypeStruct((dp,), j.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        interpret=True,
    )(jp, v)
    return u[:d]


def _rank1_blend_kernel(j_ref, u_ref, uall_ref, coef_ref, gamma_ref, o_ref):
    """One row-tile of J' = γJ + coef · u_tile ⊗ u_all."""
    gamma = gamma_ref[0]
    coef = coef_ref[0]
    o_ref[...] = gamma * j_ref[...] + coef * (
        u_ref[...][:, None] * uall_ref[...][None, :]
    )


def rank1_blend(j, u, coef, gamma):
    """J' = γJ + coef·uuᵀ, row-tiled."""
    d = j.shape[0]
    dp = _pad_rows(d)
    jp = jnp.pad(j, ((0, dp - d), (0, 0)))
    up = jnp.pad(u, (0, dp - d))
    coef_arr = jnp.reshape(coef.astype(j.dtype), (1,))
    gamma_arr = jnp.reshape(jnp.asarray(gamma, j.dtype), (1,))
    grid = (dp // BLOCK,)
    out = pl.pallas_call(
        _rank1_blend_kernel,
        out_shape=jax.ShapeDtypeStruct((dp, d), j.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK, d), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK, d), lambda i: (i, 0)),
        interpret=True,
    )(jp, up, u, coef_arr, gamma_arr)
    return out[:d]


def sm_update(inv, v, gamma):
    """The full Equation 5/6 update through the Pallas kernels.

    ``gamma`` may be a Python float or a traced scalar (the ``mkor_step``
    artifact passes it as an argument so one artifact serves any γ).
    """
    gamma = jnp.asarray(gamma, inv.dtype)
    u = matvec(inv, v)
    s = jnp.dot(v, u)
    coef = (1.0 - gamma) / (gamma * gamma * (1.0 + gamma * (1.0 - gamma) * s))
    return rank1_blend(inv, u, coef, gamma)
