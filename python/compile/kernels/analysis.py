"""L1 performance analysis: VMEM footprint and MXU-utilization estimates.

interpret=True gives CPU-numpy wallclock, which is *not* a TPU proxy
(DESIGN.md §7) — so kernel performance is assessed structurally, from the
BlockSpecs: how much VMEM does each grid step hold, how many HBM passes
over the big operand does the schedule make, and what fraction of the MXU's
128×128 systolic tiles do the chosen block shapes fill.

Run: ``python -m compile.kernels.analysis`` (from python/), or via pytest.
"""

from dataclasses import dataclass

from ..configs import PRESETS, factor_dims
from . import precond, sm_update

VMEM_BYTES = 16 * 1024 * 1024  # per-core VMEM on current TPUs
MXU = 128


@dataclass
class KernelReport:
    name: str
    vmem_per_step: int
    hbm_reads_of_J: float  # passes over the d×d operand
    hbm_writes_of_J: float
    mxu_tile_fill: float  # fraction of the 128×128 tile the blocks fill

    def fits_vmem(self) -> bool:
        return self.vmem_per_step <= VMEM_BYTES


def sm_update_report(d: int) -> KernelReport:
    """Eq. 5/6 through matvec + rank1_blend (sm_update.py).

    Per grid step the matvec holds a BLOCK×d row tile + the d-vector; the
    blend holds the same tile plus u. Whole-update HBM traffic on J: one
    read (matvec) + one read + one write (blend).
    """
    blk = sm_update.BLOCK
    vmem = blk * d * 4 + d * 4 + blk * 4  # J tile + v + u tile
    # matvec is a GEVM — it cannot fill the MXU's second dimension, and
    # the rank-1 blend is pure VPU work, so MXU fill is ~1/128: this kernel
    # is bandwidth-bound by design (O(d^2) data, O(d^2) flops).
    fill = min(blk, MXU) / MXU * (1.0 / MXU)
    return KernelReport(
        name=f"sm_update d={d}",
        vmem_per_step=vmem,
        hbm_reads_of_J=2.0,
        hbm_writes_of_J=1.0,
        mxu_tile_fill=fill,
    )


def matmul_report(m: int, k: int, n: int) -> KernelReport:
    """The tiled preconditioning matmul (precond.py)."""
    bm, bn, bk = precond.BM, precond.BN, precond.BK
    vmem = (bm * bk + bk * bn + bm * bn) * 4
    # Each A tile is read n/bn times, each B tile m/bm times; the output
    # accumulates in VMEM across the k axis (single write).
    reads = (n + bn - 1) // bn
    fill = (min(bm, MXU) / MXU) * (min(bn, MXU) / MXU)
    return KernelReport(
        name=f"matmul {m}x{k}x{n}",
        vmem_per_step=vmem,
        hbm_reads_of_J=float(reads),
        hbm_writes_of_J=1.0,
        mxu_tile_fill=fill,
    )


def preset_report(name: str):
    p = PRESETS[name]
    out = []
    dims = sorted({d for pair in factor_dims(p) for d in pair})
    for d in dims:
        out.append(sm_update_report(d))
    for (din, dout) in sorted(set(factor_dims(p))):
        out.append(matmul_report(din, din, dout))  # R⁻¹ @ grad
        out.append(matmul_report(din, dout, dout))  # (.) @ L⁻¹
    return out


def main():
    for name in PRESETS:
        print(f"== preset {name} ==")
        for r in preset_report(name):
            print(
                f"  {r.name:26s} vmem/step {r.vmem_per_step/1024:8.1f} KiB "
                f"(fits: {r.fits_vmem()}), J passes r/w {r.hbm_reads_of_J:.0f}/"
                f"{r.hbm_writes_of_J:.0f}, MXU tile fill {r.mxu_tile_fill:.2f}"
            )


if __name__ == "__main__":
    main()
