"""L2: the transformer masked-LM and the fused MKOR optimizer graph.

Everything here is build-time Python: ``aot.py`` lowers the three jitted
entry points to HLO text once, and the Rust coordinator executes them via
PJRT forever after.

Entry points (argument/result orders are the contract with
``rust/src/runtime/xla_trainer.rs`` — keep in sync):

* ``train_step(*params, tokens, targets, mask)``
    → ``(loss, *grads, *a_means, *g_means)``
  Forward + backward of the MLM, plus the per-matrix rank-1 statistics of
  Algorithm 1 lines 2–3: ``a_mean`` is the batch·seq mean of the matmul
  input, ``g_mean`` the mean of ∂L/∂(matmul output) (captured with the
  zero-perturbation trick — grads w.r.t. zero offsets added to each
  pre-activation).

* ``mkor_step(*grads, *linvs, *rinvs, *a_means, *g_means, gamma, flag)``
    → ``(*deltas, *new_linvs, *new_rinvs)``
  Lines 5–10 of Algorithm 1 for every preconditioned matrix: the Pallas
  SM factor update (gated by ``flag``), Pallas-tiled preconditioning and
  the norm rescale. Non-preconditioned parameters pass through (line 12).

* ``eval_step(*params, tokens, targets, mask)`` → ``(loss,)``

The dense layers of the transformer itself call the Pallas matmul, so the
L1 kernels genuinely sit on the lowered hot path.
"""

from typing import List, Sequence

import jax
import jax.numpy as jnp

from .configs import Preset, param_specs
from .kernels import precond as kprecond
from .kernels import sm_update as ksm


def _layer_norm(x, scale_delta, bias, eps=1e-5):
    """LayerNorm with the scale stored as a delta (applied as 1+s)."""
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * (1.0 + scale_delta) + bias


def _dense(x, w, z):
    """x @ w through the Pallas matmul, plus the zero-perturbation z used
    to capture ∂L/∂(output). x: (N, d_in), w: (d_in, d_out), z: (N, d_out)."""
    return kprecond.matmul(x, w) + z


def forward_loss(p: Preset, params: Sequence[jax.Array], zs: Sequence[jax.Array],
                 tokens, targets, mask):
    """MLM loss. Returns (loss, a_inputs) where a_inputs[i] is the input to
    preconditioned matmul i (needed for the rank-1 activation statistics)."""
    specs = param_specs(p)
    by_name = {s.name: params[i] for i, s in enumerate(specs)}
    b, s = tokens.shape
    n = b * s
    d = p.d_model
    h = p.n_heads
    dh = d // h

    a_inputs: List[jax.Array] = []
    zi = iter(zs)

    x = by_name["embed"][tokens] * jnp.sqrt(jnp.asarray(d, jnp.float32))
    x = x + by_name["pos"][None, :, :]

    def cap_dense(x2d, wname):
        a_inputs.append(x2d)
        return _dense(x2d, by_name[wname], next(zi))

    for l in range(p.n_layers):
        # --- attention ---------------------------------------------------
        xn = _layer_norm(x, by_name[f"l{l}.ln1_s"], by_name[f"l{l}.ln1_b"])
        x2 = xn.reshape(n, d)
        q = cap_dense(x2, f"l{l}.wq").reshape(b, s, h, dh)
        k = cap_dense(x2, f"l{l}.wk").reshape(b, s, h, dh)
        v = cap_dense(x2, f"l{l}.wv").reshape(b, s, h, dh)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(
            jnp.asarray(dh, jnp.float32)
        )
        att = jax.nn.softmax(att, axis=-1)
        ctx = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(n, d)
        x = x + cap_dense(ctx, f"l{l}.wo").reshape(b, s, d)
        # --- mlp ---------------------------------------------------------
        xn = _layer_norm(x, by_name[f"l{l}.ln2_s"], by_name[f"l{l}.ln2_b"])
        hdn = cap_dense(xn.reshape(n, d), f"l{l}.w1")
        hdn = jax.nn.gelu(hdn)
        x = x + cap_dense(hdn, f"l{l}.w2").reshape(b, s, d)

    x = _layer_norm(x, by_name["lnf_s"], by_name["lnf_b"])
    # Tied decoder.
    logits = x.reshape(n, d) @ by_name["embed"].T  # (n, vocab)

    tgt = targets.reshape(n)
    m = mask.reshape(n)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, tgt[:, None], axis=-1)[:, 0]
    loss = jnp.sum((logz - gold) * m) / jnp.maximum(jnp.sum(m), 1.0)
    return loss, a_inputs


def _zero_perturbations(p: Preset):
    """Zero arrays shaped like each preconditioned matmul's output."""
    n = p.batch * p.seq_len
    out = []
    for _ in range(p.n_layers):
        for dout in (p.d_model,) * 4 + (p.d_ff, p.d_model):
            out.append(jnp.zeros((n, dout), jnp.float32))
    return out


def make_train_step(p: Preset):
    """Build the jittable train_step for a preset."""

    def train_step(*args):
        specs = param_specs(p)
        np_ = len(specs)
        params = args[:np_]
        tokens, targets, mask = args[np_], args[np_ + 1], args[np_ + 2]
        zs = _zero_perturbations(p)

        def loss_fn(params, zs):
            loss, a_inputs = forward_loss(p, params, zs, tokens, targets, mask)
            a_means = [a.mean(axis=0) for a in a_inputs]
            return loss, a_means

        (loss, a_means), (gparams, gzs) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True
        )(params, zs)
        g_means = [gz.mean(axis=0) for gz in gzs]
        return (loss, *gparams, *a_means, *g_means)

    return train_step


def make_eval_step(p: Preset):
    def eval_step(*args):
        specs = param_specs(p)
        np_ = len(specs)
        params = args[:np_]
        tokens, targets, mask = args[np_], args[np_ + 1], args[np_ + 2]
        zs = _zero_perturbations(p)
        loss, _ = forward_loss(p, params, zs, tokens, targets, mask)
        return (loss,)

    return eval_step


def make_mkor_step(p: Preset):
    """Build the fused MKOR optimizer graph for a preset."""
    specs = param_specs(p)
    np_ = len(specs)
    pidx = [i for i, s in enumerate(specs) if s.precond]
    nm = len(pidx)

    def mkor_step(*args):
        grads = list(args[:np_])
        linvs = list(args[np_:np_ + nm])
        rinvs = list(args[np_ + nm:np_ + 2 * nm])
        a_means = list(args[np_ + 2 * nm:np_ + 3 * nm])
        g_means = list(args[np_ + 3 * nm:np_ + 4 * nm])
        gamma = args[np_ + 4 * nm]
        flag = args[np_ + 4 * nm + 1]

        deltas = list(grads)  # line 12 default for first-order params
        new_linvs, new_rinvs = [], []
        for j, i in enumerate(pidx):
            # Lines 7–8 (Pallas SM kernels), gated on the factor-step flag.
            lu = ksm.sm_update(linvs[j], g_means[j], gamma)
            ru = ksm.sm_update(rinvs[j], a_means[j], gamma)
            linv = jnp.where(flag > 0.5, lu, linvs[j])
            rinv = jnp.where(flag > 0.5, ru, rinvs[j])
            new_linvs.append(linv)
            new_rinvs.append(rinv)
            # Lines 9–10 (Pallas precondition + rescale).
            deltas[i] = kprecond.precond_rescaled(rinv, grads[i], linv)
        return (*deltas, *new_linvs, *new_rinvs)

    return mkor_step
