"""AOT lowering: JAX → HLO text artifacts + meta.json.

Usage (from python/): ``python -m compile.aot --out ../artifacts [--presets tiny,small]``

HLO *text* is the interchange format, not serialized HloModuleProto: jax
≥ 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import PRESETS, Preset, factor_dims, num_params, param_specs
from .model import make_eval_step, make_mkor_step, make_train_step


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def lower_preset(p: Preset, out_dir: str) -> dict:
    """Lower train/mkor/eval steps for one preset; returns meta dict."""
    specs = param_specs(p)
    fdims = factor_dims(p)
    os.makedirs(out_dir, exist_ok=True)

    param_args = [_f32(s.shape) for s in specs]
    batch_args = [
        _i32((p.batch, p.seq_len)),
        _i32((p.batch, p.seq_len)),
        _f32((p.batch, p.seq_len)),
    ]

    def write(name, fn, args):
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"  {name}: {len(text) / 1e6:.1f} MB HLO text")

    write("train_step", make_train_step(p), param_args + batch_args)

    grad_args = [_f32(s.shape) for s in specs]
    linv_args = [_f32((dout, dout)) for (_, dout) in fdims]
    rinv_args = [_f32((din, din)) for (din, _) in fdims]
    a_args = [_f32((din,)) for (din, _) in fdims]
    g_args = [_f32((dout,)) for (_, dout) in fdims]
    scalars = [_f32(()), _f32(())]  # gamma, flag
    write(
        "mkor_step",
        make_mkor_step(p),
        grad_args + linv_args + rinv_args + a_args + g_args + scalars,
    )

    write("eval_step", make_eval_step(p), param_args + batch_args)

    meta = {
        "preset": p.name,
        "vocab": p.vocab,
        "d_model": p.d_model,
        "n_layers": p.n_layers,
        "n_heads": p.n_heads,
        "d_ff": p.d_ff,
        "seq_len": p.seq_len,
        "batch": p.batch,
        "params": num_params(p),
        "factor_dims": [list(d) for d in fdims],
        "param_shapes": [list(s.shape) for s in specs],
        "param_names": [s.name for s in specs],
    }
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    return meta


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--presets", default="tiny,small")
    args = ap.parse_args()
    for name in args.presets.split(","):
        name = name.strip()
        if name not in PRESETS:
            raise SystemExit(f"unknown preset `{name}` (have {sorted(PRESETS)})")
        p = PRESETS[name]
        print(f"lowering preset `{name}` ({num_params(p) / 1e6:.1f}M params)")
        lower_preset(p, os.path.join(args.out, name))


if __name__ == "__main__":
    main()
