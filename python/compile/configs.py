"""Model presets and the parameter-layout contract shared with Rust.

This module is the single source of truth for the transformer architecture:
``aot.py`` mirrors it into ``artifacts/<preset>/meta.json``, which is what
the Rust runtime (``rust/src/runtime/artifact.rs``) reads. Field names and
orderings here are load-bearing — change them and the Rust side must change
too.
"""

from dataclasses import dataclass, field
from typing import List, Tuple


@dataclass(frozen=True)
class Preset:
    """One transformer configuration."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int
    batch: int  # per-worker batch the artifacts are lowered for


PRESETS = {
    # CI-speed smoke config (~1.1M params).
    "tiny": Preset("tiny", vocab=1024, d_model=128, n_layers=2, n_heads=4,
                   d_ff=512, seq_len=64, batch=8),
    # Default end-to-end config (~13M params).
    "small": Preset("small", vocab=4096, d_model=256, n_layers=4, n_heads=8,
                    d_ff=1024, seq_len=128, batch=8),
    # The ~100M-parameter configuration (BERT-Base-scale).
    "base": Preset("base", vocab=8192, d_model=768, n_layers=12, n_heads=12,
                   d_ff=3072, seq_len=128, batch=4),
}


@dataclass
class ParamSpec:
    """One learnable tensor."""

    name: str
    shape: Tuple[int, ...]
    # Preconditioned by MKOR factors (the x @ W matmul weights).
    precond: bool = False


def param_specs(p: Preset) -> List[ParamSpec]:
    """The flat parameter list, in artifact argument order.

    The MLM decoder is weight-tied to the embedding. LayerNorm scales are
    stored as deltas (applied as ``1 + s``) so zero-init is the identity —
    this lets the Rust side initialize every 1-D tensor to zero.
    """
    specs: List[ParamSpec] = [
        ParamSpec("embed", (p.vocab, p.d_model)),
        ParamSpec("pos", (p.seq_len, p.d_model)),
    ]
    for l in range(p.n_layers):
        for nm in ("wq", "wk", "wv", "wo"):
            specs.append(ParamSpec(f"l{l}.{nm}", (p.d_model, p.d_model), precond=True))
        specs.append(ParamSpec(f"l{l}.w1", (p.d_model, p.d_ff), precond=True))
        specs.append(ParamSpec(f"l{l}.w2", (p.d_ff, p.d_model), precond=True))
        for nm in ("ln1_s", "ln1_b", "ln2_s", "ln2_b"):
            specs.append(ParamSpec(f"l{l}.{nm}", (p.d_model,)))
    specs.append(ParamSpec("lnf_s", (p.d_model,)))
    specs.append(ParamSpec("lnf_b", (p.d_model,)))
    return specs


def factor_dims(p: Preset) -> List[Tuple[int, int]]:
    """(d_in, d_out) of each preconditioned matrix, in spec order."""
    return [s.shape for s in param_specs(p) if s.precond]  # type: ignore[return-value]


def precond_indices(p: Preset) -> List[int]:
    """Indices into the param list of the preconditioned matrices."""
    return [i for i, s in enumerate(param_specs(p)) if s.precond]


def num_params(p: Preset) -> int:
    total = 0
    for s in param_specs(p):
        n = 1
        for d in s.shape:
            n *= d
        total += n
    return total
