"""AOT path: lowering produces loadable HLO text + consistent meta.json."""

import json
import os

import pytest

from compile.aot import lower_preset, to_hlo_text
from compile.configs import Preset, factor_dims, param_specs


TEST_PRESET = Preset("aottest", vocab=64, d_model=32, n_layers=1, n_heads=2,
                     d_ff=64, seq_len=16, batch=2)


@pytest.fixture(scope="module")
def lowered(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts") / "aottest"
    meta = lower_preset(TEST_PRESET, str(out))
    return out, meta


def test_artifacts_exist_and_are_hlo_text(lowered):
    out, _ = lowered
    for name in ("train_step", "mkor_step", "eval_step"):
        path = out / f"{name}.hlo.txt"
        assert path.exists(), name
        text = path.read_text()
        # HLO text, not a serialized proto: begins with a module header and
        # contains an ENTRY computation.
        assert text.lstrip().startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_meta_matches_configs(lowered):
    out, meta = lowered
    on_disk = json.loads((out / "meta.json").read_text())
    assert on_disk == meta
    assert meta["preset"] == "aottest"
    assert meta["factor_dims"] == [list(d) for d in factor_dims(TEST_PRESET)]
    assert meta["param_shapes"] == [list(s.shape) for s in param_specs(TEST_PRESET)]
    assert len(meta["param_names"]) == len(meta["param_shapes"])


def test_hlo_text_mentions_expected_entry_arity(lowered):
    out, meta = lowered
    text = (out / "train_step.hlo.txt").read_text()
    # ENTRY must take params + tokens/targets/mask.
    n_args = len(meta["param_shapes"]) + 3
    entry = [l for l in text.splitlines() if l.strip().startswith("ENTRY")]
    assert entry, "no ENTRY line"
    assert entry[0].count("parameter") >= 0  # arity visible via param list
    assert f"p{n_args - 1}" in text or "parameter(" + str(n_args - 1) + ")" in text


def test_to_hlo_text_roundtrips_simple_fn():
    import jax
    import jax.numpy as jnp

    def f(x):
        return (x * 2.0 + 1.0,)

    lowered = jax.jit(f).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = to_hlo_text(lowered)
    assert text.lstrip().startswith("HloModule")
    assert "ENTRY" in text
