"""L2 correctness: the transformer MLM and the statistics capture."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import PRESETS, Preset, factor_dims, num_params, param_specs, precond_indices
from compile.model import make_eval_step, make_mkor_step, make_train_step

TINY = Preset("test", vocab=64, d_model=32, n_layers=2, n_heads=2,
              d_ff=64, seq_len=16, batch=4)


def init_params(p, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for s in param_specs(p):
        if len(s.shape) >= 2:
            sigma = min(0.02, 1.0 / np.sqrt(s.shape[0]))
            out.append(jnp.array(rng.standard_normal(s.shape).astype(np.float32) * sigma))
        else:
            out.append(jnp.zeros(s.shape, jnp.float32))
    return out


def random_batch(p, seed=0):
    rng = np.random.default_rng(seed)
    tokens = jnp.array(rng.integers(0, p.vocab, (p.batch, p.seq_len)), jnp.int32)
    targets = jnp.array(rng.integers(0, p.vocab, (p.batch, p.seq_len)), jnp.int32)
    mask = jnp.array((rng.random((p.batch, p.seq_len)) < 0.2).astype(np.float32))
    # At least one target.
    mask = mask.at[0, 0].set(1.0)
    return tokens, targets, mask


def test_param_specs_consistency():
    for p in PRESETS.values():
        specs = param_specs(p)
        assert len(factor_dims(p)) == 6 * p.n_layers
        assert len(precond_indices(p)) == 6 * p.n_layers
        assert specs[0].name == "embed"
        assert num_params(p) > 0


def test_base_preset_is_about_100m():
    n = num_params(PRESETS["base"])
    assert 80e6 < n < 120e6, n


def test_train_step_shapes_and_finiteness():
    p = TINY
    params = init_params(p)
    step = jax.jit(make_train_step(p))
    out = step(*params, *random_batch(p))
    np_ = len(params)
    nm = len(factor_dims(p))
    assert len(out) == 1 + np_ + 2 * nm
    loss = out[0]
    assert np.isfinite(float(loss))
    # Initial loss ≈ ln(vocab) for random init.
    assert abs(float(loss) - np.log(p.vocab)) < 1.0
    for g, spec in zip(out[1:1 + np_], param_specs(p)):
        assert g.shape == spec.shape
        assert bool(jnp.all(jnp.isfinite(g)))
    for a, (din, _) in zip(out[1 + np_:1 + np_ + nm], factor_dims(p)):
        assert a.shape == (din,)
    for g, (_, dout) in zip(out[1 + np_ + nm:], factor_dims(p)):
        assert g.shape == (dout,)


def test_gradient_matches_finite_difference():
    p = TINY
    params = init_params(p)
    batch = random_batch(p)
    step = jax.jit(make_train_step(p))
    out = step(*params, *batch)
    # Perturb one embedding entry.
    idx = 3
    eps = 1e-2
    eval_step = jax.jit(make_eval_step(p))
    pp = [q for q in params]
    pp[0] = params[0].at[1, idx].add(eps)
    lp = float(eval_step(*pp, *batch)[0])
    pp[0] = params[0].at[1, idx].add(-eps)
    lm = float(eval_step(*pp, *batch)[0])
    num = (lp - lm) / (2 * eps)
    ana = float(out[1][1, idx])
    assert abs(num - ana) < 2e-2 * (1 + abs(num)), (num, ana)


def test_g_means_match_weight_gradient_identity():
    """Consistency of the zero-perturbation capture: for each matrix,
    ∇W = Σ_pos aᵀ·g, so projecting ∇W onto the mean vectors should correlate
    with a_mean ⊗ g_mean (sanity, not equality)."""
    p = TINY
    params = init_params(p, seed=1)
    step = jax.jit(make_train_step(p))
    out = step(*params, *random_batch(p, seed=1))
    np_ = len(params)
    nm = len(factor_dims(p))
    pidx = precond_indices(p)
    grads = out[1:1 + np_]
    a_means = out[1 + np_:1 + np_ + nm]
    g_means = out[1 + np_ + nm:]
    n_pos = p.batch * p.seq_len
    for j, i in enumerate(pidx[:4]):
        w_grad = grads[i]
        rank1 = n_pos * jnp.outer(a_means[j], g_means[j])
        # Same order of magnitude and positive correlation in expectation
        # is too weak to assert per-matrix; instead check shapes + finite.
        assert rank1.shape == w_grad.shape
        assert bool(jnp.all(jnp.isfinite(rank1)))


def test_loss_decreases_under_naive_sgd():
    p = TINY
    params = init_params(p, seed=2)
    step = jax.jit(make_train_step(p))
    batch = random_batch(p, seed=2)
    np_ = len(params)
    losses = []
    for _ in range(12):
        out = step(*params, *batch)
        losses.append(float(out[0]))
        grads = out[1:1 + np_]
        params = [q - 0.5 * g for q, g in zip(params, grads)]
    assert losses[-1] < losses[0] - 0.3, losses


def test_mkor_step_identity_factors_passthrough():
    """flag=0 and identity factors: deltas == grads (rescale is a no-op on
    an identity-preconditioned gradient), factors unchanged."""
    p = TINY
    specs = param_specs(p)
    fdims = factor_dims(p)
    rng = np.random.default_rng(3)
    grads = [jnp.array(rng.standard_normal(s.shape).astype(np.float32)) for s in specs]
    linvs = [jnp.eye(dout, dtype=jnp.float32) for (_, dout) in fdims]
    rinvs = [jnp.eye(din, dtype=jnp.float32) for (din, _) in fdims]
    a_means = [jnp.zeros((din,), jnp.float32) for (din, _) in fdims]
    g_means = [jnp.zeros((dout,), jnp.float32) for (_, dout) in fdims]
    step = jax.jit(make_mkor_step(p))
    out = step(*grads, *linvs, *rinvs, *a_means, *g_means,
               jnp.float32(0.9), jnp.float32(0.0))
    np_ = len(specs)
    nm = len(fdims)
    assert len(out) == np_ + 2 * nm
    for d, g in zip(out[:np_], grads):
        np.testing.assert_allclose(np.asarray(d), np.asarray(g), rtol=1e-4, atol=1e-5)
    for l, (_, dout) in zip(out[np_:np_ + nm], fdims):
        np.testing.assert_allclose(np.asarray(l), np.eye(dout), atol=1e-6)


def test_mkor_step_factor_update_matches_ref():
    """flag=1: factor outputs equal the Eq. 5/6 oracle, and deltas are the
    rescaled preconditioned gradients."""
    from compile.kernels import ref

    p = TINY
    specs = param_specs(p)
    fdims = factor_dims(p)
    pidx = precond_indices(p)
    rng = np.random.default_rng(4)

    def spd(d):
        a = rng.standard_normal((d, d)).astype(np.float32)
        return jnp.array(a @ a.T / d + 0.2 * np.eye(d, dtype=np.float32))

    grads = [jnp.array(rng.standard_normal(s.shape).astype(np.float32)) for s in specs]
    linvs = [spd(dout) for (_, dout) in fdims]
    rinvs = [spd(din) for (din, _) in fdims]
    a_means = [jnp.array(rng.standard_normal(din).astype(np.float32)) for (din, _) in fdims]
    g_means = [jnp.array(rng.standard_normal(dout).astype(np.float32)) for (_, dout) in fdims]
    gamma = 0.95
    step = jax.jit(make_mkor_step(p))
    out = step(*grads, *linvs, *rinvs, *a_means, *g_means,
               jnp.float32(gamma), jnp.float32(1.0))
    np_ = len(specs)
    nm = len(fdims)
    for j in range(min(nm, 3)):
        want_l = ref.sm_update_ref(linvs[j], g_means[j], gamma)
        np.testing.assert_allclose(
            np.asarray(out[np_ + j]), np.asarray(want_l), rtol=2e-4, atol=2e-4
        )
        want_r = ref.sm_update_ref(rinvs[j], a_means[j], gamma)
        np.testing.assert_allclose(
            np.asarray(out[np_ + nm + j]), np.asarray(want_r), rtol=2e-4, atol=2e-4
        )
        # Delta: rescaled R⁻¹'∇L⁻¹'.
        i = pidx[j]
        raw = np.asarray(want_r) @ np.asarray(grads[i]) @ np.asarray(want_l)
        scale = np.linalg.norm(np.asarray(grads[i])) / max(np.linalg.norm(raw), 1e-30)
        np.testing.assert_allclose(
            np.asarray(out[i]), raw * scale, rtol=2e-3, atol=2e-3
        )


def test_eval_step_matches_train_step_loss():
    p = TINY
    params = init_params(p, seed=5)
    batch = random_batch(p, seed=5)
    lt = float(jax.jit(make_train_step(p))(*params, *batch)[0])
    le = float(jax.jit(make_eval_step(p))(*params, *batch)[0])
    assert abs(lt - le) < 1e-5
