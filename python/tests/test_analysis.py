"""Kernel BlockSpec analysis sanity checks (the L1 perf deliverable)."""

from compile.kernels.analysis import matmul_report, preset_report, sm_update_report


def test_sm_update_fits_vmem_up_to_4k():
    for d in (128, 1024, 3072, 4096):
        r = sm_update_report(d)
        assert r.fits_vmem(), f"d={d}: {r.vmem_per_step}"
        assert r.hbm_reads_of_J == 2.0 and r.hbm_writes_of_J == 1.0


def test_matmul_tiles_fill_mxu():
    r = matmul_report(768, 768, 3072)
    assert r.mxu_tile_fill == 1.0
    assert r.fits_vmem()


def test_all_presets_report():
    for name in ("tiny", "small", "base"):
        rs = preset_report(name)
        assert rs and all(r.fits_vmem() for r in rs)
