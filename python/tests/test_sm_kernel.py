"""L1 correctness: the Pallas SM-update kernel vs the pure-jnp oracle.

Hypothesis sweeps dimensions (including non-multiples of the block size),
value scales and γ; fixed-seed cases pin the exact formula.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.sm_update import matvec, rank1_blend, sm_update


def random_spd(d, rng, eps=0.1):
    a = rng.standard_normal((d, d)).astype(np.float32)
    return (a @ a.T / d + eps * np.eye(d)).astype(np.float32)


@settings(max_examples=25, deadline=None)
@given(
    d=st.integers(min_value=1, max_value=300),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matvec_matches_dense(d, seed):
    rng = np.random.default_rng(seed)
    j = rng.standard_normal((d, d)).astype(np.float32)
    v = rng.standard_normal(d).astype(np.float32)
    got = np.asarray(matvec(jnp.array(j), jnp.array(v)))
    want = j @ v
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(
    d=st.integers(min_value=2, max_value=200),
    gamma=st.floats(min_value=0.5, max_value=0.999),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_sm_update_matches_ref(d, gamma, seed):
    rng = np.random.default_rng(seed)
    inv = random_spd(d, rng)
    v = rng.standard_normal(d).astype(np.float32)
    got = np.asarray(sm_update(jnp.array(inv), jnp.array(v), gamma))
    want = np.asarray(ref.sm_update_ref(jnp.array(inv), jnp.array(v), gamma))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_rank1_blend_exact_small():
    j = jnp.array([[1.0, 2.0], [3.0, 4.0]], jnp.float32)
    u = jnp.array([1.0, -1.0], jnp.float32)
    out = np.asarray(rank1_blend(j, u, jnp.float32(0.5), 0.9))
    want = 0.9 * np.asarray(j) + 0.5 * np.outer([1.0, -1.0], [1.0, -1.0])
    np.testing.assert_allclose(out, want, rtol=1e-6)


def test_identity_start_first_update():
    """From J=I: u=v, s=‖v‖², J' = γI + coef vvᵀ — the exact Eq. 5 values."""
    d, gamma = 8, 0.95
    rng = np.random.default_rng(0)
    v = rng.standard_normal(d).astype(np.float32)
    got = np.asarray(sm_update(jnp.eye(d, dtype=jnp.float32), jnp.array(v), gamma))
    s = float(v @ v)
    coef = (1 - gamma) / (gamma**2 * (1 + gamma * (1 - gamma) * s))
    want = gamma * np.eye(d) + coef * np.outer(v, v)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_positive_definiteness_preserved_lemma_3_1():
    """Lemma 3.1 through the kernel: repeated updates keep J PD (checked by
    Cholesky), in the stabilized-norm regime."""
    d, gamma = 32, 0.95
    rng = np.random.default_rng(1)
    inv = jnp.array(random_spd(d, rng))
    for step in range(30):
        v = jnp.array(rng.standard_normal(d).astype(np.float32))
        inv = sm_update(inv, v, gamma)
        # Stabilize like Algorithm 1 lines 5–6 so f32 growth stays bounded.
        if float(jnp.abs(inv).sum(axis=1).max()) > 100.0:
            inv = 0.5 * inv + 0.5 * jnp.eye(d)
        np.linalg.cholesky(np.asarray(inv, dtype=np.float64))  # raises if not PD


def test_gamma_one_limit_is_identity_map():
    """γ→1: coefficient → 0 and J' → J."""
    d = 16
    rng = np.random.default_rng(2)
    inv = jnp.array(random_spd(d, rng))
    v = jnp.array(rng.standard_normal(d).astype(np.float32))
    out = sm_update(inv, v, 0.9999)
    np.testing.assert_allclose(np.asarray(out), np.asarray(inv), rtol=5e-3, atol=5e-3)


def test_traced_gamma_matches_static():
    """γ passed as a traced scalar (as the mkor_step artifact does) must
    equal the static-γ result."""
    import jax

    d = 24
    rng = np.random.default_rng(3)
    inv = jnp.array(random_spd(d, rng))
    v = jnp.array(rng.standard_normal(d).astype(np.float32))
    static = sm_update(inv, v, 0.9)
    traced = jax.jit(sm_update)(inv, v, jnp.float32(0.9))
    np.testing.assert_allclose(np.asarray(traced), np.asarray(static), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("d", [1, 255, 256, 257])
def test_block_boundary_dims(d):
    rng = np.random.default_rng(d)
    inv = random_spd(d, rng)
    v = rng.standard_normal(d).astype(np.float32)
    got = np.asarray(sm_update(jnp.array(inv), jnp.array(v), 0.9))
    want = np.asarray(ref.sm_update_ref(jnp.array(inv), jnp.array(v), 0.9))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
