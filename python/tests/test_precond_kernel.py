"""L1 correctness: the Pallas tiled matmul / preconditioner vs jnp."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.precond import matmul, precond, precond_rescaled


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=300),
    k=st.integers(min_value=1, max_value=300),
    n=st.integers(min_value=1, max_value=300),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matmul_matches_jnp(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    got = np.asarray(matmul(jnp.array(a), jnp.array(b)))
    want = np.asarray(ref.matmul_ref(jnp.array(a), jnp.array(b)))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("shape", [(127, 128, 129), (128, 128, 128), (1, 1, 1), (384, 256, 130)])
def test_matmul_block_boundaries(shape):
    m, k, n = shape
    rng = np.random.default_rng(7)
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    got = np.asarray(matmul(jnp.array(a), jnp.array(b)))
    np.testing.assert_allclose(got, a @ b, rtol=2e-4, atol=2e-4)


def test_matmul_gradients_flow():
    """custom_vjp: grads of a loss through the Pallas matmul equal jnp's."""
    rng = np.random.default_rng(8)
    a = jnp.array(rng.standard_normal((64, 32)).astype(np.float32))
    b = jnp.array(rng.standard_normal((32, 48)).astype(np.float32))
    t = jnp.array(rng.standard_normal((64, 48)).astype(np.float32))

    def loss_pallas(a, b):
        return jnp.sum((matmul(a, b) - t) ** 2)

    def loss_jnp(a, b):
        return jnp.sum((a @ b - t) ** 2)

    ga_p, gb_p = jax.grad(loss_pallas, argnums=(0, 1))(a, b)
    ga_j, gb_j = jax.grad(loss_jnp, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(np.asarray(ga_p), np.asarray(ga_j), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(gb_p), np.asarray(gb_j), rtol=1e-3, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(
    din=st.integers(min_value=2, max_value=130),
    dout=st.integers(min_value=2, max_value=130),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_precond_matches_ref(din, dout, seed):
    rng = np.random.default_rng(seed)
    rinv = rng.standard_normal((din, din)).astype(np.float32)
    grad = rng.standard_normal((din, dout)).astype(np.float32)
    linv = rng.standard_normal((dout, dout)).astype(np.float32)
    got = np.asarray(precond(jnp.array(rinv), jnp.array(grad), jnp.array(linv)))
    want = np.asarray(ref.precond_ref(jnp.array(rinv), jnp.array(grad), jnp.array(linv)))
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


def test_precond_rescaled_norm_matches_gradient():
    rng = np.random.default_rng(9)
    din, dout = 40, 24
    rinv = jnp.array((5 * np.eye(din)).astype(np.float32))
    grad = jnp.array(rng.standard_normal((din, dout)).astype(np.float32))
    linv = jnp.array(np.eye(dout).astype(np.float32))
    out = precond_rescaled(rinv, grad, linv)
    # Line 10: ‖ΔW‖_F == ‖∇W‖_F even though the raw precondition was 5×.
    np.testing.assert_allclose(
        float(jnp.linalg.norm(out)), float(jnp.linalg.norm(grad)), rtol=1e-5
    )
    # Direction preserved (rinv ∝ I, linv = I ⇒ Δ ∝ grad).
    cos = float(jnp.sum(out * grad) / (jnp.linalg.norm(out) * jnp.linalg.norm(grad)))
    assert cos > 0.999


def test_identity_preconditioning_is_noop():
    rng = np.random.default_rng(10)
    grad = jnp.array(rng.standard_normal((64, 32)).astype(np.float32))
    out = precond_rescaled(jnp.eye(64), grad, jnp.eye(32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(grad), rtol=1e-5, atol=1e-6)
